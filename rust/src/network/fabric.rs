//! In-process broadcast fabric with a seeded delay/loss model.
//!
//! The dispatcher reads time through a [`Clock`]: by default the real OS
//! clock (identical behavior to always), but handed a
//! [`crate::sim::SimClock`] the [`NetConfig`] delay model plays out in
//! *virtual* time — an hour-long `base_latency` costs no wall time, the
//! test just advances the clock (see
//! `virtual_clock_defers_delivery_until_advanced` below).

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::sim::clock::{Clock, RealClock};
use crate::util::rng::Rng;

/// Link model configuration.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// fixed per-link propagation delay
    pub base_latency: Duration,
    /// mean of the exponential jitter added per message per link
    pub jitter_mean: Duration,
    /// serialization delay = message_bytes / bandwidth (0 = infinite bw)
    pub bandwidth_bytes_per_sec: f64,
    /// iid message-loss probability per link
    pub drop_rate: f64,
    /// per-receiver latency multipliers (laggard links); empty = all 1.0
    pub latency_multipliers: Vec<f64>,
    /// seed for the fabric's delay/loss randomness
    pub seed: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            base_latency: Duration::from_micros(200),
            jitter_mean: Duration::from_micros(100),
            bandwidth_bytes_per_sec: 1e9,
            drop_rate: 0.0,
            latency_multipliers: Vec::new(),
            seed: 0xFAB,
        }
    }
}

impl NetConfig {
    /// An ideal network (zero latency/jitter/loss) for unit tests.
    pub fn ideal() -> NetConfig {
        NetConfig {
            base_latency: Duration::ZERO,
            jitter_mean: Duration::ZERO,
            bandwidth_bytes_per_sec: 0.0,
            drop_rate: 0.0,
            latency_multipliers: Vec::new(),
            seed: 0,
        }
    }
}

/// Delivery counters (shared, lock-free).
#[derive(Debug, Default)]
pub struct NetStats {
    /// broadcasts offered to the fabric (one per `broadcast` call)
    pub sent: AtomicU64,
    /// per-recipient deliveries that reached an inbox
    pub delivered: AtomicU64,
    /// per-recipient deliveries eaten by the loss model
    pub dropped: AtomicU64,
}

impl NetStats {
    /// `(sent, delivered, dropped)` read with relaxed ordering.
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.sent.load(Ordering::Relaxed),
            self.delivered.load(Ordering::Relaxed),
            self.dropped.load(Ordering::Relaxed),
        )
    }
}

/// A message in flight.
struct InFlight<T> {
    due: Instant,
    seq: u64,
    dest: usize,
    msg: T,
}

impl<T> PartialEq for InFlight<T> {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl<T> Eq for InFlight<T> {}
impl<T> PartialOrd for InFlight<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for InFlight<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap by (due, seq)
        other
            .due
            .cmp(&self.due)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

enum ToDispatcher<T> {
    Broadcast { src: usize, bytes: usize, msg: T },
    /// dynamic membership: attach a new inbox (DESIGN.md §12)
    Register { tx: Sender<T> },
    Shutdown,
}

/// One worker's attachment to the fabric.
pub struct Endpoint<T> {
    /// This endpoint's worker id (broadcasts skip it as a recipient).
    pub id: usize,
    to_net: Sender<ToDispatcher<T>>,
    inbox: Receiver<T>,
}

impl<T: Clone + Send + 'static> Endpoint<T> {
    /// Fire-and-forget broadcast to every *other* endpoint.
    pub fn broadcast(&self, msg: T, bytes: usize) {
        let _ = self.to_net.send(ToDispatcher::Broadcast {
            src: self.id,
            bytes,
            msg,
        });
    }

    /// Non-blocking poll of the next delivered message.
    pub fn try_recv(&self) -> Option<T> {
        self.inbox.try_recv().ok()
    }

    /// Blocking receive with timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<T> {
        self.inbox.recv_timeout(timeout).ok()
    }

    /// Drain everything currently queued.
    pub fn drain(&self) -> Vec<T> {
        self.inbox.try_iter().collect()
    }
}

/// The fabric: owns the dispatcher thread.
pub struct Fabric<T> {
    to_net: Sender<ToDispatcher<T>>,
    /// Shared delivery counters, readable while the fabric runs.
    pub stats: Arc<NetStats>,
    /// next worker id handed out by [`Fabric::join`] (ids 0..n are the
    /// founding endpoints)
    next_id: AtomicU64,
    handle: Option<JoinHandle<()>>,
}

impl<T: Clone + Send + 'static> Fabric<T> {
    /// Create a fabric with `n` endpoints on the real clock.
    pub fn new(n: usize, cfg: NetConfig) -> (Fabric<T>, Vec<Endpoint<T>>) {
        Fabric::new_with_clock(n, cfg, Arc::new(RealClock))
    }

    /// Create a fabric whose delay model is timed by `clock`; with a
    /// virtual clock, delivery waits for `clock` advances, not wall time.
    pub fn new_with_clock(
        n: usize,
        cfg: NetConfig,
        clock: Arc<dyn Clock>,
    ) -> (Fabric<T>, Vec<Endpoint<T>>) {
        assert!(n >= 1);
        let (to_net, from_endpoints) = channel::<ToDispatcher<T>>();
        let mut inbox_txs = Vec::with_capacity(n);
        let mut endpoints = Vec::with_capacity(n);
        for id in 0..n {
            let (tx, rx) = channel::<T>();
            inbox_txs.push(tx);
            endpoints.push(Endpoint {
                id,
                to_net: to_net.clone(),
                inbox: rx,
            });
        }
        let stats = Arc::new(NetStats::default());
        let stats2 = Arc::clone(&stats);
        let handle = std::thread::Builder::new()
            .name("net-fabric".into())
            .spawn(move || dispatcher(from_endpoints, inbox_txs, cfg, stats2, clock))
            .expect("spawn fabric dispatcher");
        (
            Fabric {
                to_net,
                stats,
                next_id: AtomicU64::new(n as u64),
                handle: Some(handle),
            },
            endpoints,
        )
    }

    /// Dynamic membership: attach a new endpoint to a *running* fabric.
    /// The joiner gets the next dense worker id and hears every broadcast
    /// offered after its registration reaches the dispatcher — earlier
    /// traffic is gone, exactly TMSN's join semantics (the joiner catches
    /// up from the next strictly-better broadcast it hears).
    pub fn join(&self) -> Endpoint<T> {
        let (tx, rx) = channel::<T>();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) as usize;
        let _ = self.to_net.send(ToDispatcher::Register { tx });
        Endpoint {
            id,
            to_net: self.to_net.clone(),
            inbox: rx,
        }
    }

    /// Stop the dispatcher (undelivered messages are discarded).
    pub fn shutdown(mut self) {
        let _ = self.to_net.send(ToDispatcher::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl<T> Drop for Fabric<T> {
    fn drop(&mut self) {
        let _ = self.to_net.send(ToDispatcher::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn dispatcher<T: Clone + Send>(
    incoming: Receiver<ToDispatcher<T>>,
    mut inboxes: Vec<Sender<T>>,
    cfg: NetConfig,
    stats: Arc<NetStats>,
    clock: Arc<dyn Clock>,
) {
    let mut rng = Rng::new(cfg.seed);
    let mut heap: BinaryHeap<InFlight<T>> = BinaryHeap::new();
    let mut seq = 0u64;
    loop {
        // deliver everything due
        let now = clock.now();
        while heap.peek().map_or(false, |m| m.due <= now) {
            let m = heap.pop().unwrap();
            if inboxes[m.dest].send(m.msg).is_ok() {
                stats.delivered.fetch_add(1, Ordering::Relaxed);
            }
        }
        // wait for the next due time or a new message; under a virtual
        // clock the channel still waits in *real* time, so cap the wait
        // and re-read the clock — due times move only when it advances
        let mut timeout = heap
            .peek()
            .map(|m| m.due.saturating_duration_since(clock.now()))
            .unwrap_or(Duration::from_millis(50));
        if clock.is_virtual() && !heap.is_empty() {
            // an empty heap has nothing clock-gated: new broadcasts wake
            // the channel on their own, so keep the long idle heartbeat
            timeout = timeout.min(Duration::from_millis(1));
        }
        match incoming.recv_timeout(timeout) {
            Ok(ToDispatcher::Broadcast { src, bytes, msg }) => {
                stats.sent.fetch_add(1, Ordering::Relaxed);
                let now = clock.now();
                let ser = if cfg.bandwidth_bytes_per_sec > 0.0 {
                    Duration::from_secs_f64(bytes as f64 / cfg.bandwidth_bytes_per_sec)
                } else {
                    Duration::ZERO
                };
                for dest in 0..inboxes.len() {
                    if dest == src {
                        continue;
                    }
                    if cfg.drop_rate > 0.0 && rng.bernoulli(cfg.drop_rate) {
                        stats.dropped.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    let jitter = if cfg.jitter_mean > Duration::ZERO {
                        Duration::from_secs_f64(
                            rng.exponential(1.0 / cfg.jitter_mean.as_secs_f64()),
                        )
                    } else {
                        Duration::ZERO
                    };
                    let mult = cfg
                        .latency_multipliers
                        .get(dest)
                        .copied()
                        .unwrap_or(1.0);
                    let delay = (cfg.base_latency + jitter).mul_f64(mult) + ser;
                    heap.push(InFlight {
                        due: now + delay,
                        seq,
                        dest,
                        msg: msg.clone(),
                    });
                    seq += 1;
                }
            }
            Ok(ToDispatcher::Register { tx }) => {
                // joiner's inbox index == its dense id: Register messages
                // from the single Fabric handle are FIFO, so ids and
                // indices agree
                inboxes.push(tx);
            }
            Ok(ToDispatcher::Shutdown) | Err(RecvTimeoutError::Disconnected) => return,
            Err(RecvTimeoutError::Timeout) => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_reaches_all_other_endpoints() {
        let (fabric, eps) = Fabric::new(4, NetConfig::ideal());
        eps[1].broadcast("hello".to_string(), 5);
        for (i, ep) in eps.iter().enumerate() {
            if i == 1 {
                assert!(ep.recv_timeout(Duration::from_millis(50)).is_none());
            } else {
                assert_eq!(
                    ep.recv_timeout(Duration::from_secs(2)).as_deref(),
                    Some("hello")
                );
            }
        }
        let (sent, delivered, dropped) = fabric.stats.snapshot();
        assert_eq!((sent, delivered, dropped), (1, 3, 0));
        fabric.shutdown();
    }

    #[test]
    fn latency_delays_delivery() {
        let cfg = NetConfig {
            base_latency: Duration::from_millis(50),
            jitter_mean: Duration::ZERO,
            ..NetConfig::ideal()
        };
        let (fabric, eps) = Fabric::new(2, cfg);
        let t0 = Instant::now();
        eps[0].broadcast(1u32, 4);
        let got = eps[1].recv_timeout(Duration::from_secs(2));
        assert_eq!(got, Some(1));
        assert!(t0.elapsed() >= Duration::from_millis(45), "{:?}", t0.elapsed());
        fabric.shutdown();
    }

    #[test]
    fn laggard_multiplier_slows_one_link() {
        let cfg = NetConfig {
            base_latency: Duration::from_millis(20),
            jitter_mean: Duration::ZERO,
            latency_multipliers: vec![1.0, 1.0, 5.0],
            ..NetConfig::ideal()
        };
        let (fabric, eps) = Fabric::new(3, cfg);
        eps[0].broadcast(7u8, 1);
        let t0 = Instant::now();
        assert!(eps[1].recv_timeout(Duration::from_secs(2)).is_some());
        let fast = t0.elapsed();
        assert!(eps[2].recv_timeout(Duration::from_secs(2)).is_some());
        let slow = t0.elapsed();
        assert!(slow > fast, "slow={slow:?} fast={fast:?}");
        assert!(slow >= Duration::from_millis(90), "slow={slow:?}");
        fabric.shutdown();
    }

    #[test]
    fn drop_rate_one_drops_everything() {
        let cfg = NetConfig {
            drop_rate: 1.0,
            ..NetConfig::ideal()
        };
        let (fabric, eps) = Fabric::new(2, cfg);
        eps[0].broadcast(1i32, 4);
        assert!(eps[1].recv_timeout(Duration::from_millis(100)).is_none());
        let (_, delivered, dropped) = fabric.stats.snapshot();
        assert_eq!(delivered, 0);
        assert_eq!(dropped, 1);
        fabric.shutdown();
    }

    #[test]
    fn messages_ordered_per_fixed_latency() {
        let (fabric, eps) = Fabric::new(2, NetConfig::ideal());
        for i in 0..10u32 {
            eps[0].broadcast(i, 4);
        }
        let mut got = Vec::new();
        while got.len() < 10 {
            if let Some(v) = eps[1].recv_timeout(Duration::from_secs(2)) {
                got.push(v);
            } else {
                break;
            }
        }
        assert_eq!(got, (0..10).collect::<Vec<_>>());
        fabric.shutdown();
    }

    #[test]
    fn bandwidth_adds_serialization_delay() {
        let cfg = NetConfig {
            bandwidth_bytes_per_sec: 1e6, // 1 MB/s
            ..NetConfig::ideal()
        };
        let (fabric, eps) = Fabric::new(2, cfg);
        let t0 = Instant::now();
        eps[0].broadcast(0u8, 100_000); // 100 KB -> 100 ms
        assert!(eps[1].recv_timeout(Duration::from_secs(2)).is_some());
        assert!(t0.elapsed() >= Duration::from_millis(80), "{:?}", t0.elapsed());
        fabric.shutdown();
    }

    // ---- degenerate NetConfig values: never panic, counters consistent ---

    #[test]
    fn single_endpoint_cluster_is_a_noop_network() {
        // n = 1: broadcasts have no recipients; nothing is delivered,
        // nothing is dropped, drain is empty, shutdown is clean.
        let (fabric, eps) = Fabric::new(1, NetConfig::default());
        for _ in 0..10 {
            eps[0].broadcast(1u8, 1);
        }
        std::thread::sleep(Duration::from_millis(50));
        assert!(eps[0].drain().is_empty());
        let (sent, delivered, dropped) = fabric.stats.snapshot();
        assert_eq!((sent, delivered, dropped), (10, 0, 0));
        fabric.shutdown();
    }

    #[test]
    fn zero_bandwidth_means_unthrottled_serialization() {
        // bandwidth_bytes_per_sec == 0 is the documented "infinite
        // bandwidth" sentinel: a huge message must not add delay.
        let cfg = NetConfig {
            bandwidth_bytes_per_sec: 0.0,
            ..NetConfig::ideal()
        };
        let (fabric, eps) = Fabric::new(2, cfg);
        eps[0].broadcast(7u8, usize::MAX >> 8); // absurd byte count
        assert!(eps[1].recv_timeout(Duration::from_secs(2)).is_some());
        fabric.shutdown();
    }

    #[test]
    fn zero_byte_message_with_tiny_bandwidth() {
        // 1 B/s bandwidth with a 0-byte message: serialization delay is
        // exactly zero, not NaN/panic territory.
        let cfg = NetConfig {
            bandwidth_bytes_per_sec: 1.0,
            ..NetConfig::ideal()
        };
        let (fabric, eps) = Fabric::new(2, cfg);
        eps[0].broadcast(3u8, 0);
        assert_eq!(eps[1].recv_timeout(Duration::from_secs(2)), Some(3));
        fabric.shutdown();
    }

    #[test]
    fn huge_latency_messages_discarded_on_shutdown() {
        // an hour of latency: undelivered in-flight messages are discarded
        // by shutdown (not counted dropped — drops are the loss model)
        let cfg = NetConfig {
            base_latency: Duration::from_secs(3600),
            jitter_mean: Duration::ZERO,
            ..NetConfig::ideal()
        };
        let (fabric, eps) = Fabric::new(3, cfg);
        eps[0].broadcast(9u8, 1);
        std::thread::sleep(Duration::from_millis(20));
        let (sent, delivered, dropped) = fabric.stats.snapshot();
        assert_eq!((sent, delivered, dropped), (1, 0, 0));
        fabric.shutdown(); // must return promptly, not wait an hour
    }

    #[test]
    fn stats_partition_offered_messages_under_loss() {
        // with drop_rate 0.5 every offered message is either delivered or
        // counted dropped — no third fate, no double counting
        let cfg = NetConfig {
            drop_rate: 0.5,
            seed: 99,
            ..NetConfig::ideal()
        };
        let (fabric, eps) = Fabric::new(3, cfg);
        for i in 0..100u32 {
            eps[(i % 3) as usize].broadcast(i, 4);
        }
        let offered = 100u64 * 2; // n - 1 recipients per broadcast
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let (sent, delivered, dropped) = fabric.stats.snapshot();
            assert_eq!(sent, 100);
            assert!(delivered + dropped <= offered, "{delivered}+{dropped}");
            if delivered + dropped == offered {
                assert!(delivered > 0 && dropped > 0, "seeded coin too lopsided");
                break;
            }
            assert!(Instant::now() < deadline, "counters never settled");
            std::thread::sleep(Duration::from_millis(5));
        }
        fabric.shutdown();
    }

    #[test]
    fn extreme_latency_multipliers_dont_panic() {
        let cfg = NetConfig {
            base_latency: Duration::from_micros(10),
            jitter_mean: Duration::ZERO,
            // zero multiplier (instant link) and a huge one together
            latency_multipliers: vec![0.0, 1.0, 1e6],
            ..NetConfig::ideal()
        };
        let (fabric, eps) = Fabric::new(3, cfg);
        eps[1].broadcast(1u8, 1);
        assert!(eps[0].recv_timeout(Duration::from_secs(2)).is_some());
        // endpoint 2's delivery is ~10s out; shutdown discards it cleanly
        fabric.shutdown();
    }

    #[test]
    fn virtual_clock_defers_delivery_until_advanced() {
        use crate::sim::SimClock;
        let clock = Arc::new(SimClock::new());
        let cfg = NetConfig {
            base_latency: Duration::from_secs(3600),
            jitter_mean: Duration::ZERO,
            ..NetConfig::ideal()
        };
        let (fabric, eps) = Fabric::<u8>::new_with_clock(2, cfg, clock.clone());
        eps[0].broadcast(42, 1);
        // an hour of *virtual* latency: nothing arrives in real 50 ms
        assert!(eps[1].recv_timeout(Duration::from_millis(50)).is_none());
        // advancing the clock past the due time releases the delivery
        clock.advance(Duration::from_secs(7200));
        assert_eq!(eps[1].recv_timeout(Duration::from_secs(2)), Some(42));
        fabric.shutdown();
    }

    #[test]
    fn drop_fabric_joins_dispatcher() {
        let (fabric, eps) = Fabric::new(2, NetConfig::ideal());
        eps[0].broadcast(1u8, 1);
        drop(fabric); // must not hang
        drop(eps);
    }

    #[test]
    fn join_attaches_a_live_endpoint_mid_run() {
        let (fabric, eps) = Fabric::new(2, NetConfig::ideal());
        let joiner = fabric.join();
        assert_eq!(joiner.id, 2, "dense ids continue past the founders");
        // give the Register message time to reach the dispatcher
        std::thread::sleep(Duration::from_millis(50));

        // the joiner hears subsequent broadcasts...
        eps[0].broadcast("post-join".to_string(), 9);
        assert_eq!(
            joiner.recv_timeout(Duration::from_secs(2)).as_deref(),
            Some("post-join")
        );
        assert_eq!(
            eps[1].recv_timeout(Duration::from_secs(2)).as_deref(),
            Some("post-join")
        );
        // ...and its own broadcasts reach the founders but not itself
        joiner.broadcast("from-joiner".to_string(), 11);
        for ep in &eps {
            assert_eq!(
                ep.recv_timeout(Duration::from_secs(2)).as_deref(),
                Some("from-joiner")
            );
        }
        assert!(joiner.recv_timeout(Duration::from_millis(100)).is_none());
        fabric.shutdown();
    }

    #[test]
    fn drain_collects_buffered() {
        let (fabric, eps) = Fabric::new(3, NetConfig::ideal());
        eps[0].broadcast(1u8, 1);
        eps[2].broadcast(2u8, 1);
        std::thread::sleep(Duration::from_millis(100));
        let got = eps[1].drain();
        assert_eq!(got.len(), 2);
        fabric.shutdown();
    }
}
