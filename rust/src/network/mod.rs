//! Broadcast network fabric.
//!
//! TMSN's only communication primitive is *broadcast with no
//! acknowledgement*: a worker publishes a certified payload and keeps
//! working; receivers observe the message after a per-link delay. There is
//! no head node and no barrier anywhere in this module — the fabric is a
//! delay + loss model, not a coordinator.
//!
//! Both transports are payload-generic: [`Fabric`]/[`Endpoint`] carry any
//! `T: Clone + Send`, and [`TcpEndpoint`] frames any
//! [`crate::tmsn::Payload`] via its own `encode`/`decode` — no workload
//! types appear anywhere in this module.
//!
//! The paper ran on EC2 with real NICs; here the fabric is an in-process
//! simulator with seeded, configurable per-link latency (base +
//! exponential jitter), bandwidth-proportional serialization delay,
//! message loss, per-worker laggard multipliers, and crash injection —
//! the knobs behind the Figure-1 timeline and the resilience experiments
//! (E2, E6 in DESIGN.md).

pub mod fabric;
pub mod tcp;

pub use fabric::{Endpoint, Fabric, NetConfig, NetStats};
pub use tcp::TcpEndpoint;
