//! Broadcast network fabric.
//!
//! TMSN's only communication primitive is *broadcast with no
//! acknowledgement*: a worker publishes a certified payload and keeps
//! working; receivers observe the message after a per-link delay. There is
//! no head node and no barrier anywhere in this module — the fabric is a
//! delay + loss model, not a coordinator.
//!
//! Both transports are payload-generic: [`Fabric`]/[`Endpoint`] carry any
//! `T: Clone + Send`, and [`TcpEndpoint`] frames any
//! [`crate::tmsn::Payload`] via its own `encode`/`decode` — no workload
//! types appear anywhere in this module.
//!
//! The paper ran on EC2 with real NICs; here the fabric is an in-process
//! simulator with seeded, configurable per-link latency (base +
//! exponential jitter), bandwidth-proportional serialization delay,
//! message loss, per-worker laggard multipliers, and crash injection —
//! the knobs behind the Figure-1 timeline and the resilience experiments
//! (E2, E6 in DESIGN.md).
//!
//! # Example
//!
//! ```
//! use std::time::Duration;
//! use sparrow::network::{Fabric, NetConfig};
//!
//! // three endpoints on an ideal (zero-latency, lossless) fabric
//! let (fabric, eps) = Fabric::new(3, NetConfig::ideal());
//! eps[0].broadcast("certified model v1".to_string(), 18);
//! for ep in &eps[1..] {
//!     let got = ep.recv_timeout(Duration::from_secs(2));
//!     assert_eq!(got.as_deref(), Some("certified model v1"));
//! }
//! // the sender never hears its own broadcast
//! assert!(eps[0].try_recv().is_none());
//! fabric.shutdown();
//! ```

#![warn(missing_docs)]

pub mod chaos;
pub mod fabric;
pub mod pex;
pub mod tcp;

pub use chaos::{ChaosFault, ChaosProxy, ChaosRules};
pub use fabric::{Endpoint, Fabric, NetConfig, NetStats};
pub use tcp::{PeerInfo, TcpEndpoint, TcpTuning};

/// How a published payload fans out to the cluster (DESIGN.md §12).
///
/// TMSN's protocol layer only requires *eventual* dissemination of
/// strictly-better certificates; it never requires that every publish
/// reach every peer directly. That freedom is what makes gossip legal:
///
/// * [`BroadcastMode::Full`] — every publish is sent to all `n − 1`
///   peers. Wire cost of a full round is `O(n²)`; the origin's NIC does
///   `O(n)` serialized writes per publish.
/// * [`BroadcastMode::Fanout`] — every publish is sent to `k` seeded
///   random peers with a TTL; a receiver that *accepts* the payload
///   (strictly better than its own) re-forwards it to `k` peers with
///   `ttl − 1`. Dominated payloads die where they land, so only the
///   improving frontier floods. Per-node send cost is `O(k)` per hop and
///   duplicate deliveries are suppressed by `(origin, seq, cert)` dedup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BroadcastMode {
    /// send every publish to all peers (the PR-5 default)
    Full,
    /// gossip: send/forward to `k` random peers, `ttl` hops max
    Fanout {
        /// peers contacted per publish/forward (≥ 1)
        k: usize,
        /// maximum forwarding hops; `0` means "auto" (resolved to the
        /// cluster size by [`BroadcastMode::resolved_ttl`], which always
        /// covers the alive-ring worst case)
        ttl: u32,
    },
}

impl Default for BroadcastMode {
    fn default() -> Self {
        BroadcastMode::Full
    }
}

impl BroadcastMode {
    /// Parse a CLI spelling: `full`, `fanout` (k = 3), `fanout4`, or
    /// `fanout:4`.
    pub fn parse(s: &str) -> Result<BroadcastMode, String> {
        let s = s.trim();
        if s == "full" {
            return Ok(BroadcastMode::Full);
        }
        if let Some(rest) = s.strip_prefix("fanout") {
            let rest = rest.strip_prefix(':').unwrap_or(rest);
            let k = if rest.is_empty() {
                3
            } else {
                rest.parse::<usize>().map_err(|_| format!("bad fanout degree {rest:?}"))?
            };
            if k == 0 {
                return Err("fanout degree must be >= 1".into());
            }
            return Ok(BroadcastMode::Fanout { k, ttl: 0 });
        }
        Err(format!("unknown broadcast mode {s:?} (expected full|fanout[K])"))
    }

    /// True for any fanout variant.
    pub fn is_fanout(&self) -> bool {
        matches!(self, BroadcastMode::Fanout { .. })
    }

    /// The effective TTL for an `n`-worker cluster: an explicit `ttl` is
    /// kept; the `0` sentinel resolves to `n`, which bounds the longest
    /// alive-ring path and therefore guarantees an accepted payload can
    /// reach every alive worker.
    pub fn resolved_ttl(&self, n: usize) -> u32 {
        match *self {
            BroadcastMode::Full => 0,
            BroadcastMode::Fanout { ttl: 0, .. } => n as u32,
            BroadcastMode::Fanout { ttl, .. } => ttl,
        }
    }
}

#[cfg(test)]
mod broadcast_mode_tests {
    use super::BroadcastMode;

    #[test]
    fn parse_accepts_the_documented_spellings() {
        assert_eq!(BroadcastMode::parse("full").unwrap(), BroadcastMode::Full);
        assert_eq!(
            BroadcastMode::parse("fanout").unwrap(),
            BroadcastMode::Fanout { k: 3, ttl: 0 }
        );
        assert_eq!(
            BroadcastMode::parse("fanout5").unwrap(),
            BroadcastMode::Fanout { k: 5, ttl: 0 }
        );
        assert_eq!(
            BroadcastMode::parse(" fanout:2 ").unwrap(),
            BroadcastMode::Fanout { k: 2, ttl: 0 }
        );
        assert!(BroadcastMode::parse("fanout0").is_err());
        assert!(BroadcastMode::parse("ring").is_err());
        assert!(BroadcastMode::parse("fanoutx").is_err());
    }

    #[test]
    fn ttl_zero_resolves_to_cluster_size() {
        let m = BroadcastMode::Fanout { k: 3, ttl: 0 };
        assert_eq!(m.resolved_ttl(40), 40);
        let m = BroadcastMode::Fanout { k: 3, ttl: 7 };
        assert_eq!(m.resolved_ttl(40), 7);
        assert_eq!(BroadcastMode::Full.resolved_ttl(40), 0);
    }
}
