//! Broadcast network fabric.
//!
//! TMSN's only communication primitive is *broadcast with no
//! acknowledgement*: a worker publishes a certified payload and keeps
//! working; receivers observe the message after a per-link delay. There is
//! no head node and no barrier anywhere in this module — the fabric is a
//! delay + loss model, not a coordinator.
//!
//! Both transports are payload-generic: [`Fabric`]/[`Endpoint`] carry any
//! `T: Clone + Send`, and [`TcpEndpoint`] frames any
//! [`crate::tmsn::Payload`] via its own `encode`/`decode` — no workload
//! types appear anywhere in this module.
//!
//! The paper ran on EC2 with real NICs; here the fabric is an in-process
//! simulator with seeded, configurable per-link latency (base +
//! exponential jitter), bandwidth-proportional serialization delay,
//! message loss, per-worker laggard multipliers, and crash injection —
//! the knobs behind the Figure-1 timeline and the resilience experiments
//! (E2, E6 in DESIGN.md).
//!
//! # Example
//!
//! ```
//! use std::time::Duration;
//! use sparrow::network::{Fabric, NetConfig};
//!
//! // three endpoints on an ideal (zero-latency, lossless) fabric
//! let (fabric, eps) = Fabric::new(3, NetConfig::ideal());
//! eps[0].broadcast("certified model v1".to_string(), 18);
//! for ep in &eps[1..] {
//!     let got = ep.recv_timeout(Duration::from_secs(2));
//!     assert_eq!(got.as_deref(), Some("certified model v1"));
//! }
//! // the sender never hears its own broadcast
//! assert!(eps[0].try_recv().is_none());
//! fabric.shutdown();
//! ```

#![warn(missing_docs)]

pub mod fabric;
pub mod tcp;

pub use fabric::{Endpoint, Fabric, NetConfig, NetStats};
pub use tcp::TcpEndpoint;
