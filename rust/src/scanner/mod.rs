//! The Scanner (paper §4.1, Alg. 2).
//!
//! Scans the in-memory sample sequentially (in batches), refreshing weights
//! incrementally and accumulating per-candidate edge statistics, and stops
//! as soon as the sequential stopping rule certifies some candidate's true
//! advantage ≥ γ. If a scan budget passes with no certification the target
//! γ is halved (Alg. 2's `γ ← γ/2`); a full pass over the sample with no
//! certification returns `Exhausted` (Alg. 2's `Fail`), prompting the
//! worker to resample. Between batches the worker may interrupt the scan
//! when a better remote model arrives (the TMSN receive path).

pub mod backend;
#[cfg(feature = "simd")]
pub mod simd;

pub use backend::{lane_kernel, BatchResult, BinnedBackend, NativeBackend, ScanBackend, BIN_CHUNK};

use crate::boosting::{CandidateGrid, EdgeMatrix};
use crate::data::{BinSpec, BinnedBatch, DataBlock, SampleSet};
use crate::model::{StrongRule, Stump};
use crate::stopping::{CandidateStats, StoppingRule};

/// Outcome of one scanner invocation (one boosting iteration attempt).
#[derive(Debug, Clone, PartialEq)]
pub enum ScanOutcome {
    /// A candidate was certified at advantage γ.
    Found {
        stump: Stump,
        gamma: f64,
        scanned: u64,
    },
    /// Full pass, nothing certified (worker should resample / γ exhausted).
    Exhausted { scanned: u64 },
    /// The interrupt callback asked to stop (remote model accepted).
    Interrupted { scanned: u64 },
}

/// Scanner configuration (a slice of `TrainConfig`).
#[derive(Debug, Clone)]
pub struct ScannerConfig {
    pub batch: usize,
    /// initial target advantage γ₀ per invocation
    pub gamma0: f64,
    /// give up the invocation when γ would drop below this
    pub gamma_min: f64,
    /// examples scanned before γ halves (Alg. 2's `M`);
    /// 0 = auto: `max(256, m/8)` so γ can drop to a certifiable level
    /// within a single pass over the sample
    pub scan_budget: u64,
    /// stopping-rule sweep cadence in batches; 0 = auto:
    /// `max(1, stripe_width·nthr / batch)`, which keeps the sweep cost
    /// below the scan cost on wide stripes. Budget-crossing (γ-halving)
    /// and final batches always sweep regardless of cadence.
    pub sweep_every: usize,
}

impl Default for ScannerConfig {
    fn default() -> Self {
        ScannerConfig {
            batch: 128,
            gamma0: 0.25,
            gamma_min: 0.001,
            scan_budget: 0,
            sweep_every: 0,
        }
    }
}

impl ScannerConfig {
    /// The stopping-rule sweep cadence in effect for a stripe of
    /// `stripe_width` features under `nthr` thresholds: the explicit
    /// `sweep_every` when set, else the auto amortization
    /// `max(1, stripe_width·nthr / batch)`. The single source of truth
    /// for the formula — `run_pass` and the sweep-lag regression test
    /// both derive the interval from here, so they cannot drift apart.
    pub fn effective_sweep_every(&self, stripe_width: usize, nthr: usize) -> u64 {
        if self.sweep_every == 0 {
            ((stripe_width * nthr) / self.batch).max(1) as u64
        } else {
            self.sweep_every as u64
        }
    }
}

/// The scanner: owns the candidate grid (full width), the worker's feature
/// stripe, the compute backend and the stopping rule.
pub struct Scanner {
    pub grid: CandidateGrid,
    pub stripe: (usize, usize),
    backend: Box<dyn ScanBackend>,
    rule: Box<dyn StoppingRule>,
    cfg: ScannerConfig,
    /// circular cursor into the sample (persists across invocations — the
    /// `i` threaded through Alg. 1/2)
    cursor: usize,
    /// scratch batch buffers
    scratch: Scratch,
    /// quantization spec for the binned engine, derived lazily from
    /// grid + stripe when the backend wants bins
    bin_spec: Option<BinSpec>,
    /// total examples scanned over the scanner's lifetime (diagnostics)
    pub total_scanned: u64,
    /// γ-halving events (diagnostics / GammaShrink events)
    pub gamma_shrinks: u64,
}

#[derive(Default)]
struct Scratch {
    block: Option<DataBlock>,
    w_ref: Vec<f32>,
    score_ref: Vec<f32>,
    len_ref: Vec<u32>,
    idx: Vec<usize>,
    /// batch bins gathered from the sample's prebuilt BinnedStripe
    bins: BinnedBatch,
    /// reused batch output; its `edges` is the pass accumulator
    result: BatchResult,
}

impl Scanner {
    pub fn new(
        grid: CandidateGrid,
        stripe: (usize, usize),
        backend: Box<dyn ScanBackend>,
        rule: Box<dyn StoppingRule>,
        cfg: ScannerConfig,
    ) -> Scanner {
        assert!(stripe.0 < stripe.1 && stripe.1 <= grid.f);
        Scanner {
            grid,
            stripe,
            backend,
            rule,
            cfg,
            cursor: 0,
            scratch: Scratch::default(),
            bin_spec: None,
            total_scanned: 0,
            gamma_shrinks: 0,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Live override of the per-invocation starting target γ₀ (the admin
    /// `config.set_gamma` / `config.gamma_reset` nudges, DESIGN.md §10).
    /// Takes effect at the next `run_pass`; γ still halves from the new
    /// value on budget exhaustion and `gamma_min` is unchanged.
    pub fn set_gamma0(&mut self, gamma0: f64) {
        assert!(gamma0 > 0.0, "gamma0 must be positive");
        self.cfg.gamma0 = gamma0;
    }

    /// Live override of the stopping-rule sweep cadence (the admin
    /// `config.set_sweep` nudge). `0` restores the auto cadence.
    pub fn set_sweep_every(&mut self, sweep_every: usize) {
        self.cfg.sweep_every = sweep_every;
    }

    /// Current per-invocation starting target γ₀.
    pub fn gamma0(&self) -> f64 {
        self.cfg.gamma0
    }

    /// One scanner invocation: scan up to one full pass over `sample`,
    /// looking for a candidate with certified advantage ≥ γ (γ starts at
    /// γ₀ and halves every `scan_budget` examples).
    ///
    /// `interrupt` is polled between batches; returning `true` aborts the
    /// scan (the worker accepted a remote model).
    pub fn run_pass(
        &mut self,
        sample: &mut SampleSet,
        model: &StrongRule,
        mut interrupt: impl FnMut() -> bool,
    ) -> ScanOutcome {
        let m = sample.len();
        if m == 0 {
            return ScanOutcome::Exhausted { scanned: 0 };
        }
        let budget = if self.cfg.scan_budget == 0 {
            (m as u64 / 8).max(256)
        } else {
            self.cfg.scan_budget
        };
        // amortized stopping-rule sweeps: on wide stripes a full
        // stripe×thresholds×polarity sweep per batch would dominate the
        // scan itself, so sweep every `stripe_width·nthr / batch` batches
        // (γ-halving and final batches always sweep)
        let sweep_every = self
            .cfg
            .effective_sweep_every(self.stripe.1 - self.stripe.0, self.grid.nthr);
        // binned engine: the sample must carry its quantized stripe view.
        // Prebuilt by the samplers at install time, so this is normally a
        // shape check; a cold sample (tests, ad-hoc callers) builds here —
        // once per sample, reused across every pass and γ-retry.
        if self.backend.wants_bins() {
            if self.bin_spec.is_none() {
                self.bin_spec = Some(self.grid.bin_spec(self.stripe));
            }
            sample.ensure_binned(self.bin_spec.as_ref().unwrap());
        }
        let mut gamma = self.cfg.gamma0;
        // integer halving counter (Alg. 2's halving index) — γ itself is
        // derived, never round-tripped back out of a float
        let mut halvings = 0u64;
        let mut batches = 0u64;
        let mut scanned = 0u64;
        let model_len = model.len() as u32;
        // the pass accumulator is the reused scratch's edge matrix — the
        // backend adds each batch directly into it (no per-batch alloc)
        self.scratch.result.reset(self.grid.f, self.grid.nthr);

        while scanned < m as u64 {
            if interrupt() {
                return ScanOutcome::Interrupted { scanned };
            }
            let take = (self.cfg.batch as u64).min(m as u64 - scanned) as usize;
            self.scan_chunk(sample, model, take);
            // write back refreshed weights/scores
            for (k, &i) in self.scratch.idx.iter().enumerate() {
                sample.set_weight(
                    i,
                    self.scratch.result.scores[k],
                    self.scratch.result.weights[k],
                    model_len,
                );
            }
            scanned += take as u64;
            self.total_scanned += take as u64;
            batches += 1;

            // γ halving on budget exhaustion (Alg. 2: m > M)
            let mut halved = false;
            while scanned >= budget * (halvings + 1) {
                gamma /= 2.0;
                halvings += 1;
                halved = true;
                self.gamma_shrinks += 1;
                if gamma < self.cfg.gamma_min {
                    return ScanOutcome::Exhausted { scanned };
                }
            }

            // stopping-rule sweep over the stripe candidates (both signs),
            // amortized to the cadence; γ-halving and final batches always
            // sweep so early stopping lags a per-batch sweep by at most one
            // interval
            if batches % sweep_every == 0 || halved || scanned >= m as u64 {
                if let Some((stump, g)) = self.check_candidates(&self.scratch.result.edges, gamma)
                {
                    return ScanOutcome::Found {
                        stump,
                        gamma: g,
                        scanned,
                    };
                }
            }
        }
        ScanOutcome::Exhausted { scanned }
    }

    /// Read the next `take` examples (circular) into scratch and run the
    /// backend's zero-allocation batch step (edges accumulate into the
    /// reused `scratch.result`).
    fn scan_chunk(&mut self, sample: &SampleSet, model: &StrongRule, take: usize) {
        let m = sample.len();
        let f = sample.data.f;
        let block = self
            .scratch
            .block
            .get_or_insert_with(|| DataBlock::empty(f));
        block.n = 0;
        block.features.clear();
        block.labels.clear();
        self.scratch.w_ref.clear();
        self.scratch.score_ref.clear();
        self.scratch.len_ref.clear();
        self.scratch.idx.clear();
        for _ in 0..take {
            let i = self.cursor;
            self.cursor = (self.cursor + 1) % m;
            block.push(sample.data.row(i), sample.data.label(i));
            self.scratch.w_ref.push(sample.w_last[i]);
            self.scratch.score_ref.push(sample.score_last[i]);
            self.scratch.len_ref.push(sample.model_len_last[i]);
            self.scratch.idx.push(i);
        }
        let bins = if self.backend.wants_bins() {
            let stripe_bins = sample
                .binned
                .as_ref()
                .expect("binned stripe prepared at pass start");
            self.scratch.bins.gather(stripe_bins, &self.scratch.idx);
            Some(&self.scratch.bins)
        } else {
            None
        };
        self.backend.scan_batch_into(
            block,
            bins,
            &self.scratch.w_ref,
            &self.scratch.score_ref,
            &self.scratch.len_ref,
            model,
            &self.grid,
            self.stripe,
            &mut self.scratch.result,
        );
    }

    /// Does any stripe candidate (either polarity) fire at target `gamma`?
    fn check_candidates(&self, accum: &EdgeMatrix, gamma: f64) -> Option<(Stump, f64)> {
        let (fs, fe) = self.stripe;
        let mut best: Option<(Stump, f64, f64)> = None; // (stump, γ, deviation)
        for f in fs..fe {
            for t in 0..self.grid.nthr {
                let e = accum.edge(f, t);
                for sign in [1.0f64, -1.0] {
                    let stats = CandidateStats {
                        m: e * sign,
                        sum_w: accum.sum_w,
                        sum_w2: accum.sum_w2,
                        count: accum.count,
                    };
                    if self.rule.fires(&stats, gamma) {
                        let dev = stats.deviation(gamma);
                        if best.as_ref().map_or(true, |b| dev > b.2) {
                            best = Some((
                                Stump::new(f as u32, self.grid.row(f)[t], sign as f32),
                                gamma,
                                dev,
                            ));
                        }
                    }
                }
            }
        }
        best.map(|(s, g, _)| (s, g))
    }

    /// Reset the circular cursor (used when a new sample is installed).
    pub fn reset_cursor(&mut self) {
        self.cursor = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stopping::LilRule;
    use crate::util::rng::Rng;

    /// A sample where feature 0 equals the label (a perfect weak rule) and
    /// the rest are noise.
    fn easy_sample(n: usize, f: usize, seed: u64) -> SampleSet {
        let mut rng = Rng::new(seed);
        let mut block = DataBlock::empty(f);
        for _ in 0..n {
            let y = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            let mut row: Vec<f32> = (0..f).map(|_| rng.gauss() as f32).collect();
            row[0] = y * (1.0 + rng.f32());
            block.push(&row, y);
        }
        SampleSet::fresh(block, vec![0.0; n], 0)
    }

    fn noise_sample(n: usize, f: usize, seed: u64) -> SampleSet {
        let mut rng = Rng::new(seed);
        let mut block = DataBlock::empty(f);
        for _ in 0..n {
            let y = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            let row: Vec<f32> = (0..f).map(|_| rng.gauss() as f32).collect();
            block.push(&row, y);
        }
        SampleSet::fresh(block, vec![0.0; n], 0)
    }

    fn scanner(f: usize, gamma0: f64) -> Scanner {
        Scanner::new(
            CandidateGrid::uniform(f, 3, -1.0, 1.0),
            (0, f),
            Box::new(NativeBackend),
            Box::new(LilRule::default()),
            ScannerConfig {
                batch: 64,
                gamma0,
                gamma_min: 0.001,
                scan_budget: 0,
                sweep_every: 0,
            },
        )
    }

    #[test]
    fn finds_perfect_feature() {
        let mut sample = easy_sample(2000, 4, 1);
        let mut sc = scanner(4, 0.25);
        let model = StrongRule::new();
        match sc.run_pass(&mut sample, &model, || false) {
            ScanOutcome::Found { stump, gamma, scanned } => {
                assert_eq!(stump.feature, 0, "found {stump}");
                assert!(gamma > 0.0);
                // early stopping: far fewer than the full pass
                assert!(scanned < 2000, "scanned={scanned}");
            }
            other => panic!("expected Found, got {other:?}"),
        }
    }

    #[test]
    fn exhausts_on_pure_noise() {
        let mut sample = noise_sample(500, 4, 2);
        let mut sc = scanner(4, 0.25);
        let model = StrongRule::new();
        match sc.run_pass(&mut sample, &model, || false) {
            ScanOutcome::Exhausted { scanned } => assert_eq!(scanned, 500),
            other => panic!("expected Exhausted, got {other:?}"),
        }
        // γ was halved along the way
        assert!(sc.gamma_shrinks > 0);
    }

    #[test]
    fn interrupt_aborts_scan() {
        let mut sample = noise_sample(1000, 4, 3);
        let mut sc = scanner(4, 0.25);
        let model = StrongRule::new();
        let mut polls = 0;
        let out = sc.run_pass(&mut sample, &model, || {
            polls += 1;
            polls > 2
        });
        match out {
            ScanOutcome::Interrupted { scanned } => {
                assert!(scanned <= 200, "scanned={scanned}");
            }
            other => panic!("expected Interrupted, got {other:?}"),
        }
    }

    #[test]
    fn weights_refreshed_during_scan() {
        let mut sample = easy_sample(300, 2, 4);
        let mut sc = scanner(2, 0.4);
        // a model that's already good on feature 0 → weights shrink
        let mut model = StrongRule::new();
        model.push(Stump::new(0, 0.0, 1.0), 0.8);
        let _ = sc.run_pass(&mut sample, &model, || false);
        // every scanned example has model_len_last == 1 and weight < 1
        let scanned_any = sample.model_len_last.iter().any(|&l| l == 1);
        assert!(scanned_any);
        for i in 0..sample.len() {
            if sample.model_len_last[i] == 1 {
                assert!(sample.w_last[i] < 1.0);
            }
        }
    }

    #[test]
    fn stripe_restricts_found_features() {
        // perfect feature 0, but the worker owns features [2, 4) → it must
        // NOT certify feature 0
        let mut sample = easy_sample(1500, 4, 5);
        let mut sc = Scanner::new(
            CandidateGrid::uniform(4, 3, -1.0, 1.0),
            (2, 4),
            Box::new(NativeBackend),
            Box::new(LilRule::default()),
            ScannerConfig::default(),
        );
        let model = StrongRule::new();
        match sc.run_pass(&mut sample, &model, || false) {
            ScanOutcome::Found { stump, .. } => {
                assert!((2..4).contains(&(stump.feature as usize)), "{stump}");
            }
            ScanOutcome::Exhausted { .. } => {} // fine: no signal in stripe
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn cursor_persists_across_invocations() {
        let mut sample = noise_sample(100, 2, 6);
        let mut sc = scanner(2, 0.25);
        let model = StrongRule::new();
        let _ = sc.run_pass(&mut sample, &model, || false);
        assert_eq!(sc.cursor, 0); // full pass wrapped exactly
        let mut polls = 0;
        let _ = sc.run_pass(&mut sample, &model, || {
            polls += 1;
            polls > 1
        });
        assert_ne!(sc.cursor, 0); // partial pass left the cursor mid-sample
        sc.reset_cursor();
        assert_eq!(sc.cursor, 0);
    }

    #[test]
    fn binned_engine_matches_rows_outcome() {
        // the engine knob must not change a single certified answer: rows
        // and binned (any thread count) produce the identical ScanOutcome
        // and identical refreshed weights on the same sample
        for threads in [1usize, 3] {
            let mut sample_rows = easy_sample(2000, 4, 11);
            let mut sample_binned = sample_rows.clone();
            let mut rows = scanner(4, 0.25);
            let mut binned = Scanner::new(
                CandidateGrid::uniform(4, 3, -1.0, 1.0),
                (0, 4),
                Box::new(BinnedBackend::new(threads)),
                Box::new(LilRule::default()),
                ScannerConfig {
                    batch: 64,
                    gamma0: 0.25,
                    gamma_min: 0.001,
                    scan_budget: 0,
                    sweep_every: 0,
                },
            );
            let model = StrongRule::new();
            let a = rows.run_pass(&mut sample_rows, &model, || false);
            let b = binned.run_pass(&mut sample_binned, &model, || false);
            assert_eq!(a, b, "threads={threads}");
            assert_eq!(sample_rows.w_last, sample_binned.w_last);
            // second invocation continues from identical cursors/state
            let a2 = rows.run_pass(&mut sample_rows, &model, || false);
            let b2 = binned.run_pass(&mut sample_binned, &model, || false);
            assert_eq!(a2, b2, "threads={threads} (second pass)");
        }
    }

    #[test]
    fn binned_engine_builds_bins_once_per_sample() {
        // a cold sample gets its stripe view on the first pass; further
        // passes reuse it (same allocation shape, no rebuild)
        let mut sample = noise_sample(300, 4, 12);
        assert!(sample.binned.is_none());
        let mut sc = Scanner::new(
            CandidateGrid::uniform(4, 3, -1.0, 1.0),
            (1, 3),
            Box::new(BinnedBackend::new(2)),
            Box::new(LilRule::default()),
            ScannerConfig::default(),
        );
        let _ = sc.run_pass(&mut sample, &StrongRule::new(), || false);
        let built = sample.binned.clone().expect("bins built at pass start");
        assert_eq!(built.stripe, (1, 3));
        assert_eq!(built.n, 300);
        let _ = sc.run_pass(&mut sample, &StrongRule::new(), || false);
        assert_eq!(sample.binned.as_ref().unwrap(), &built, "reused, not rebuilt");
    }

    #[test]
    fn amortized_sweep_fires_within_one_interval_of_per_batch_baseline() {
        // satellite regression: on a wide stripe the auto cadence sweeps
        // every stripe_width·nthr/batch batches; early stopping may lag a
        // per-batch sweep by at most one interval of examples
        let f = 64;
        let nthr = 8;
        let batch = 16;
        let mut rng = Rng::new(13);
        let mut block = DataBlock::empty(f);
        for _ in 0..4000 {
            let y = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            let mut row: Vec<f32> = (0..f).map(|_| rng.gauss() as f32).collect();
            row[0] = y * (1.0 + rng.f32());
            block.push(&row, y);
        }
        let sample = SampleSet::fresh(block, vec![0.0; 4000], 0);
        let run = |sweep_every: usize| {
            let mut sc = Scanner::new(
                CandidateGrid::uniform(f, nthr, -1.0, 1.0),
                (0, f),
                Box::new(NativeBackend),
                Box::new(LilRule::default()),
                ScannerConfig {
                    batch,
                    gamma0: 0.25,
                    gamma_min: 0.001,
                    scan_budget: 1_000_000, // no halving noise
                    sweep_every,
                },
            );
            let mut s = sample.clone();
            sc.run_pass(&mut s, &StrongRule::new(), || false)
        };
        // the interval comes from the same formula run_pass uses — the
        // cadence and this regression test cannot drift apart
        let interval = ScannerConfig {
            batch,
            sweep_every: 0,
            ..ScannerConfig::default()
        }
        .effective_sweep_every(f, nthr) as usize;
        assert!(interval > 1, "test requires a wide stripe");
        let (base, amortized) = (run(1), run(0));
        match (base, amortized) {
            (
                ScanOutcome::Found { scanned: s1, stump: st1, .. },
                ScanOutcome::Found { scanned: s2, stump: st2, .. },
            ) => {
                assert_eq!(st1.feature, 0);
                assert_eq!(st2.feature, 0);
                assert!(s2 >= s1, "amortized cannot fire earlier");
                assert!(
                    s2 - s1 <= (interval * batch) as u64,
                    "amortized lagged more than one sweep interval: {s1} -> {s2}"
                );
            }
            other => panic!("expected Found/Found, got {other:?}"),
        }
    }

    #[test]
    fn gamma_budget_halves_target() {
        // weak-but-real signal at small advantage: γ₀ too ambitious, the
        // scanner must halve down to a certifiable level within the pass
        let mut rng = Rng::new(7);
        let mut block = DataBlock::empty(2);
        let n = 20_000;
        for _ in 0..n {
            let y = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            // feature 0 agrees with y 65% of the time → corr 0.3, adv 0.15
            let agree = rng.bernoulli(0.65);
            let x0 = if agree { y } else { -y } * (0.5 + rng.f32());
            block.push(&[x0, rng.gauss() as f32], y);
        }
        let mut sample = SampleSet::fresh(block, vec![0.0; n], 0);
        let mut sc = Scanner::new(
            CandidateGrid::uniform(2, 1, -0.5, 0.5),
            (0, 2),
            Box::new(NativeBackend),
            Box::new(LilRule::default()),
            ScannerConfig {
                batch: 256,
                gamma0: 0.45, // unreachable
                gamma_min: 0.001,
                scan_budget: 2000,
                sweep_every: 0,
            },
        );
        match sc.run_pass(&mut sample, &StrongRule::new(), || false) {
            ScanOutcome::Found { stump, gamma, .. } => {
                assert_eq!(stump.feature, 0);
                assert!(gamma < 0.45, "gamma={gamma}");
                assert!(sc.gamma_shrinks >= 1);
            }
            other => panic!("expected Found, got {other:?}"),
        }
    }

    #[test]
    fn live_gamma_override_applies_next_pass() {
        let mut rng = Rng::new(11);
        let mut block = DataBlock::empty(1);
        let n = 2_000;
        for _ in 0..n {
            let y = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            block.push(&[y * (0.5 + rng.f32())], y);
        }
        let mut sample = SampleSet::fresh(block, vec![0.0; n], 0);
        let mut sc = Scanner::new(
            CandidateGrid::uniform(1, 1, -0.5, 0.5),
            (0, 1),
            Box::new(NativeBackend),
            Box::new(LilRule::default()),
            ScannerConfig::default(),
        );
        assert_eq!(sc.gamma0(), 0.25);
        sc.set_gamma0(0.05);
        assert_eq!(sc.gamma0(), 0.05);
        // the override is what the next pass starts from: a perfectly
        // separable feature certifies with γ ≥ the (low) new target
        match sc.run_pass(&mut sample, &StrongRule::new(), || false) {
            ScanOutcome::Found { gamma, .. } => assert!(gamma >= 0.05, "gamma={gamma}"),
            other => panic!("expected Found, got {other:?}"),
        }
        sc.set_sweep_every(3); // smoke: cadence override is accepted
        sc.set_sweep_every(0);
    }
}
