//! Scan-batch compute backends.
//!
//! One batch step = (re)score a block of examples under the current model,
//! refresh their weights incrementally, and accumulate candidate edges —
//! the computation AOT-lowered in `python/compile/model.py::scan_batch`.
//! Two CPU engines implement it (selected by `--scan-engine`, DESIGN.md
//! §8):
//!
//! * [`NativeBackend`] (`rows`, default) — the row-major per-example
//!   linear threshold search, bit-compatible with the L1 Pallas kernel;
//! * [`BinnedBackend`] (`binned`) — branch-free bucket accumulation over
//!   the sample's prebuilt column-major `u8` bins, optionally sharded over
//!   `--scan-threads` scoped threads with a merge order that is fixed by
//!   construction, so results are identical for every thread count.
//!
//! The PJRT-backed backends live in `crate::runtime` and are selected via
//! `config::Backend` (ablation A4).
//!
//! The primary entry is the zero-allocation [`ScanBackend::scan_batch_into`]:
//! the caller owns a [`BatchResult`] scratch that is reused across every
//! batch of a pass, and the batch's edge/scalar contributions are
//! accumulated directly into its `edges` matrix (no per-batch `EdgeMatrix`
//! + merge). [`ScanBackend::scan_batch`] remains as an allocating
//! convenience wrapper for tests, benches and baselines.

use crate::boosting::{
    edges::{accumulate_edges_stripe_into, fold_buckets_par},
    CandidateGrid, EdgeMatrix,
};
use crate::data::{BinnedBatch, DataBlock};
use crate::model::StrongRule;

/// Which lane kernel `--scan-simd` can engage on this build + CPU:
/// `"avx2"` or `"portable"` when built with `--features simd`, else
/// `"compiled-out"` (the default build carries only the scalar loop).
#[cfg(feature = "simd")]
pub fn lane_kernel() -> &'static str {
    crate::scanner::simd::active_lane_kernel()
}

/// Which lane kernel `--scan-simd` can engage on this build + CPU:
/// `"avx2"` or `"portable"` when built with `--features simd`, else
/// `"compiled-out"` (the default build carries only the scalar loop).
#[cfg(not(feature = "simd"))]
pub fn lane_kernel() -> &'static str {
    "compiled-out"
}

/// Caller-owned scratch + result of scan batches.
///
/// `scores`/`weights` hold the *current batch* (cleared and refilled each
/// call); `edges` is the **pass accumulator** — every batch adds its
/// contributions, so the caller zeroes it once per pass via
/// [`BatchResult::reset`] instead of allocating per batch.
#[derive(Debug, Clone)]
pub struct BatchResult {
    /// per-example strong-rule score under the *current* model
    pub scores: Vec<f32>,
    /// per-example refreshed weight
    pub weights: Vec<f32>,
    /// accumulated candidate edges (full grid width; only the stripe
    /// columns are required to be filled) + stopping-rule scalars
    pub edges: EdgeMatrix,
    /// bucket scratch for the row engine's edge pass — lives here so the
    /// caller-owned scratch travels with the result across batches
    pub(crate) bucket: Vec<f64>,
}

impl BatchResult {
    /// Fresh scratch shaped to a grid.
    pub fn zeros(f: usize, nthr: usize) -> BatchResult {
        BatchResult {
            scores: Vec::new(),
            weights: Vec::new(),
            edges: EdgeMatrix::zeros(f, nthr),
            bucket: Vec::new(),
        }
    }

    /// Reset for a new pass: clear the per-batch vectors and zero the edge
    /// accumulator in place (reshaping only if the grid changed).
    pub fn reset(&mut self, f: usize, nthr: usize) {
        self.scores.clear();
        self.weights.clear();
        if self.edges.f == f && self.edges.nthr == nthr {
            self.edges.reset();
        } else {
            self.edges = EdgeMatrix::zeros(f, nthr);
        }
    }
}

impl Default for BatchResult {
    fn default() -> Self {
        BatchResult::zeros(0, 0)
    }
}

/// A compute backend for scan batches.
pub trait ScanBackend: Send {
    /// Process one batch into caller-owned scratch — the zero-allocation
    /// path the scanner drives.
    ///
    /// * `block` — the examples (full feature width).
    /// * `bins` — the batch's quantized stripe view (column-major `u8`),
    ///   gathered by the scanner when [`ScanBackend::wants_bins`] is true;
    ///   row engines receive `None` and ignore it.
    /// * `w_ref`, `score_ref` — the cached `(w_l, H_l(x))` pair per example:
    ///   weights satisfy `w = w_ref · exp(−y·(H(x) − score_ref))` for ANY
    ///   consistent reference pair, which is what makes the incremental
    ///   update exact (§4.1).
    /// * `model_len_ref` — length of the model that produced `score_ref`
    ///   (lets the native path evaluate only the new suffix).
    /// * `grid` — full candidate grid; `stripe` — the `[start, end)` range
    ///   of features this worker owns.
    /// * `out` — `scores`/`weights` are cleared and refilled for this
    ///   batch; the batch's edges and stopping scalars are **accumulated**
    ///   into `out.edges` (zero it at pass start with [`BatchResult::reset`]).
    #[allow(clippy::too_many_arguments)]
    fn scan_batch_into(
        &mut self,
        block: &DataBlock,
        bins: Option<&BinnedBatch>,
        w_ref: &[f32],
        score_ref: &[f32],
        model_len_ref: &[u32],
        model: &StrongRule,
        grid: &CandidateGrid,
        stripe: (usize, usize),
        out: &mut BatchResult,
    );

    /// Allocating convenience wrapper: a fresh [`BatchResult`] per call
    /// (tests, benches, one-shot callers).
    #[allow(clippy::too_many_arguments)]
    fn scan_batch(
        &mut self,
        block: &DataBlock,
        w_ref: &[f32],
        score_ref: &[f32],
        model_len_ref: &[u32],
        model: &StrongRule,
        grid: &CandidateGrid,
        stripe: (usize, usize),
    ) -> BatchResult {
        let mut out = BatchResult::zeros(grid.f, grid.nthr);
        self.scan_batch_into(
            block, None, w_ref, score_ref, model_len_ref, model, grid, stripe, &mut out,
        );
        out
    }

    /// Does this backend consume the quantized [`BinnedBatch`] view? The
    /// scanner gathers batch bins (and keeps the sample's `BinnedStripe`
    /// fresh) only when this is true.
    fn wants_bins(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str;
}

/// Incremental suffix scoring + weight refresh shared by the CPU engines
/// (§4.1): clears and refills `scores`/`weights` for this batch.
fn refresh_scores(
    block: &DataBlock,
    w_ref: &[f32],
    score_ref: &[f32],
    model_len_ref: &[u32],
    model: &StrongRule,
    scores: &mut Vec<f32>,
    weights: &mut Vec<f32>,
) {
    let n = block.n;
    debug_assert_eq!(w_ref.len(), n);
    debug_assert_eq!(score_ref.len(), n);
    debug_assert_eq!(model_len_ref.len(), n);
    scores.clear();
    weights.clear();
    scores.reserve(n);
    weights.reserve(n);
    for i in 0..n {
        let row = block.row(i);
        // incremental: only the suffix the reference hasn't seen
        let delta = model.score_suffix(row, model_len_ref[i] as usize);
        let score = score_ref[i] + delta;
        let w = w_ref[i] * (-(block.label(i)) * delta).exp();
        scores.push(score);
        weights.push(w);
    }
}

/// Pure-Rust row engine: incremental suffix scoring + striped edge pass
/// with a per-example linear threshold search.
#[derive(Debug, Default)]
pub struct NativeBackend;

impl ScanBackend for NativeBackend {
    fn scan_batch_into(
        &mut self,
        block: &DataBlock,
        _bins: Option<&BinnedBatch>,
        w_ref: &[f32],
        score_ref: &[f32],
        model_len_ref: &[u32],
        model: &StrongRule,
        grid: &CandidateGrid,
        stripe: (usize, usize),
        out: &mut BatchResult,
    ) {
        let BatchResult {
            scores,
            weights,
            edges,
            bucket,
        } = out;
        refresh_scores(block, w_ref, score_ref, model_len_ref, model, scores, weights);
        accumulate_edges_stripe_into(block, weights, grid, stripe, edges, bucket);
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Fixed sharding granularity of the binned engine: the batch is cut into
/// contiguous chunks of this many examples; every chunk accumulates its
/// own bucket partial (from 0.0, in example order) and the partials are
/// merged in ascending chunk order. Chunk boundaries — and therefore the
/// f64 summation tree — are a function of the batch alone, never of
/// `--scan-threads`, so the result is **identical for every thread
/// count**. A batch of at most `BIN_CHUNK` examples (the production
/// default of 128 included) is a single chunk, making the binned engine
/// bit-identical to the row engine there.
pub const BIN_CHUNK: usize = 512;

/// Binned columnar engine (DESIGN.md §8): branch-free bucket accumulation
/// `hist[bin[i]] += u[i]` per stripe column over the sample's prebuilt
/// `u8` bins, sharded across `threads` scoped workers by contiguous
/// example ranges. Suffix scoring / weight refresh stays on the row view.
#[derive(Debug)]
pub struct BinnedBackend {
    threads: usize,
    /// bucket accumulation runs the lane-widened kernels (DESIGN.md §14)
    /// instead of the scalar scatter — bit-identical by construction,
    /// only reachable when built with `--features simd`
    simd: bool,
    /// signed contributions u = w·y for the current batch
    u: Vec<f64>,
    /// per-chunk bucket partials, `(num_chunks × width × (nthr+1))`
    partials: Vec<f64>,
    /// merged batch bucket, `(width × (nthr+1))`
    bucket: Vec<f64>,
}

impl BinnedBackend {
    /// An engine that shards batch accumulation over `threads` workers
    /// (1 = fully inline; results are identical for every value). Uses
    /// the scalar bucket loop — [`BinnedBackend::with_simd`] opts into
    /// the lane kernels.
    pub fn new(threads: usize) -> BinnedBackend {
        BinnedBackend::with_simd(threads, false)
    }

    /// Like [`BinnedBackend::new`], with an explicit kernel choice:
    /// `simd = true` runs the lane-widened bucket accumulation
    /// (DESIGN.md §14 — bit-identical to the scalar loop for every
    /// input). Panics if the lane kernels were not compiled in
    /// (`--features simd`); `config::TrainConfig::validate` surfaces
    /// that as a `--scan-simd on` error before any backend is built.
    pub fn with_simd(threads: usize, simd: bool) -> BinnedBackend {
        assert!(threads >= 1, "scan-threads must be >= 1");
        assert!(
            !simd || cfg!(feature = "simd"),
            "lane kernels requested but compiled out (build with --features simd)"
        );
        BinnedBackend {
            threads,
            simd,
            u: Vec::new(),
            partials: Vec::new(),
            bucket: Vec::new(),
        }
    }

    /// The bucket-accumulation kernel this engine runs: `"scalar"`, or
    /// the active lane kernel (`"avx2"`/`"portable"`) when opted in via
    /// [`BinnedBackend::with_simd`].
    pub fn kernel(&self) -> &'static str {
        if self.simd {
            lane_kernel()
        } else {
            "scalar"
        }
    }

    /// The engine's compute core, minus the (row-view) scoring step:
    /// accumulate one batch's stopping scalars and signed contributions
    /// `u = w·y` (batch order — the same f64 operation order as the row
    /// engine's example loop), then bucket-accumulate and fold the edges
    /// into `accum`. Public so the §Perf benches can time the edge pass
    /// head-to-head against `accumulate_edges_stripe`.
    pub fn accumulate_batch(
        &mut self,
        bins: &BinnedBatch,
        weights: &[f32],
        labels: &[f32],
        nthr: usize,
        stripe: (usize, usize),
        accum: &mut EdgeMatrix,
    ) {
        let n = bins.n;
        debug_assert_eq!(weights.len(), n);
        debug_assert_eq!(labels.len(), n);
        self.u.clear();
        self.u.reserve(n);
        let mut sum_w = 0.0f64;
        let mut sum_w2 = 0.0f64;
        for i in 0..n {
            let wi = weights[i] as f64;
            sum_w += wi.abs();
            sum_w2 += wi * wi;
            self.u.push(wi * labels[i] as f64);
        }
        accum.sum_w += sum_w;
        accum.sum_w2 += sum_w2;
        accum.count += n as u64;
        self.accumulate(bins, nthr, stripe, accum);
    }

    /// Bucket-accumulate the batch over its bin columns and fold into
    /// `accum` (which must already carry this batch's stopping scalars).
    fn accumulate(
        &mut self,
        bins: &BinnedBatch,
        nthr: usize,
        stripe: (usize, usize),
        accum: &mut EdgeMatrix,
    ) {
        let n = bins.n;
        let width = bins.width;
        debug_assert_eq!(width, stripe.1 - stripe.0);
        let stride = width * (nthr + 1);
        let nchunks = n.div_ceil(BIN_CHUNK).max(1);
        self.partials.clear();
        self.partials.resize(nchunks * stride, 0.0);

        let u = &self.u;
        // always false unless built with --features simd (ctor-asserted)
        let lanes = self.simd;
        // one chunk's partial: columns outer, examples inner — for any
        // fixed (column, bucket) slot the adds land in ascending example
        // order, exactly like the row engine's per-slot order. The lane
        // kernels preserve that per-slot order exactly (DESIGN.md §14),
        // so both arms produce the identical partial, bit for bit.
        let run_chunk = |c: usize, p: &mut [f64]| {
            let lo = c * BIN_CHUNK;
            let hi = ((c + 1) * BIN_CHUNK).min(n);
            for col in 0..width {
                let colbins = &bins.bins[col * n..(col + 1) * n];
                let hist = &mut p[col * (nthr + 1)..(col + 1) * (nthr + 1)];
                if lanes {
                    #[cfg(feature = "simd")]
                    crate::scanner::simd::accumulate_column(colbins, u, lo, hi, hist);
                } else {
                    for i in lo..hi {
                        hist[colbins[i] as usize] += u[i];
                    }
                }
            }
        };

        let eff = self.threads.min(nchunks);
        if eff <= 1 {
            for (c, p) in self.partials.chunks_mut(stride).enumerate() {
                run_chunk(c, p);
            }
        } else {
            // contiguous chunk ranges per rank; each rank writes only its
            // own disjoint partial slices, so no synchronization is needed
            let per = nchunks.div_ceil(eff);
            let run = &run_chunk;
            std::thread::scope(|s| {
                for (r, shard) in self.partials.chunks_mut(per * stride).enumerate() {
                    s.spawn(move || {
                        for (k, p) in shard.chunks_mut(stride).enumerate() {
                            run(r * per + k, p);
                        }
                    });
                }
            });
        }

        // deterministic rank-ordered merge: partials fold in ascending
        // chunk order, independent of how threads divided them
        self.bucket.clear();
        self.bucket.resize(stride, 0.0);
        for chunk in self.partials.chunks(stride) {
            for (a, &p) in self.bucket.iter_mut().zip(chunk) {
                *a += p;
            }
        }
        // buckets → edges: the row engine's reverse prefix sum, threaded
        // across feature columns on wide stripes (disjoint-slice writes
        // merged in ascending column order — bit-identical for any
        // thread count, DESIGN.md §14)
        fold_buckets_par(&self.bucket, stripe, nthr, accum, self.threads);
    }
}

impl ScanBackend for BinnedBackend {
    fn scan_batch_into(
        &mut self,
        block: &DataBlock,
        bins: Option<&BinnedBatch>,
        w_ref: &[f32],
        score_ref: &[f32],
        model_len_ref: &[u32],
        model: &StrongRule,
        grid: &CandidateGrid,
        stripe: (usize, usize),
        out: &mut BatchResult,
    ) {
        let BatchResult {
            scores,
            weights,
            edges,
            bucket,
        } = out;
        refresh_scores(block, w_ref, score_ref, model_len_ref, model, scores, weights);
        match bins {
            Some(b) => {
                debug_assert_eq!(b.n, block.n);
                self.accumulate_batch(b, weights, &block.labels, grid.nthr, stripe, edges);
            }
            // no quantized view (a caller outside the scanner): the row
            // path computes the identical result, just slower
            None => accumulate_edges_stripe_into(block, weights, grid, stripe, edges, bucket),
        }
    }

    fn wants_bins(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "binned"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::boosting::edges::edges_bruteforce;
    use crate::model::Stump;
    use crate::util::prop::{gen, prop_check};
    use crate::util::rng::Rng;

    fn random_block(rng: &mut Rng, n: usize, f: usize) -> DataBlock {
        DataBlock::new(
            n,
            f,
            gen::normal_vec(rng, n * f),
            gen::labels(rng, n, 0.4),
        )
    }

    fn random_model(rng: &mut Rng, f: usize, t: usize) -> StrongRule {
        let mut m = StrongRule::new();
        for _ in 0..t {
            m.push(
                Stump::new(
                    rng.below(f as u64) as u32,
                    rng.gauss() as f32 * 0.5,
                    if rng.bernoulli(0.5) { 1.0 } else { -1.0 },
                ),
                0.05 + rng.f64() as f32 * 0.3,
            );
        }
        m
    }

    /// Gather a full-batch `BinnedBatch` for `block` under `grid`/`stripe`.
    fn bins_for(block: &DataBlock, grid: &CandidateGrid, stripe: (usize, usize)) -> BinnedBatch {
        let stripe_bins = grid.bin_spec(stripe).bin_block(block);
        let idx: Vec<usize> = (0..block.n).collect();
        let mut b = BinnedBatch::default();
        b.gather(&stripe_bins, &idx);
        b
    }

    #[test]
    fn fresh_reference_matches_direct_scoring() {
        let mut rng = Rng::new(1);
        let block = random_block(&mut rng, 50, 8);
        let model = random_model(&mut rng, 8, 5);
        let grid = CandidateGrid::uniform(8, 3, -1.5, 1.5);
        let w_ref = vec![1.0f32; 50];
        let score_ref = vec![0.0f32; 50];
        let len_ref = vec![0u32; 50];
        let mut be = NativeBackend;
        let r = be.scan_batch(&block, &w_ref, &score_ref, &len_ref, &model, &grid, (0, 8));
        for i in 0..50 {
            let want_score = model.score(block.row(i));
            assert!((r.scores[i] - want_score).abs() < 1e-5);
            let want_w = (-(block.label(i)) * want_score).exp();
            assert!((r.weights[i] - want_w).abs() < 1e-4);
        }
    }

    #[test]
    fn prop_incremental_equals_fresh() {
        // updating from a cached mid-model state gives identical weights
        // to scoring from scratch — the §4.1 invariant.
        prop_check("incremental == fresh", 30, |rng| {
            let n = gen::size(rng, 5, 60);
            let f = gen::size(rng, 2, 8);
            let block = random_block(rng, n, f);
            let model = random_model(rng, f, 6);
            let grid = CandidateGrid::uniform(f, 2, -1.0, 1.0);
            let mut be = NativeBackend;

            // fresh path
            let fresh = be.scan_batch(
                &block,
                &vec![1.0; n],
                &vec![0.0; n],
                &vec![0u32; n],
                &model,
                &grid,
                (0, f),
            );
            // cached path: reference = model prefix of length 3
            let mut prefix = StrongRule::new();
            for t in 0..3 {
                prefix.push(model.stumps()[t], model.alphas()[t]);
            }
            let mid = be.scan_batch(
                &block,
                &vec![1.0; n],
                &vec![0.0; n],
                &vec![0u32; n],
                &prefix,
                &grid,
                (0, f),
            );
            let inc = be.scan_batch(
                &block,
                &mid.weights,
                &mid.scores,
                &vec![3u32; n],
                &model,
                &grid,
                (0, f),
            );
            for i in 0..n {
                if (inc.scores[i] - fresh.scores[i]).abs() > 1e-4 {
                    return Err(format!("score {i}: {} vs {}", inc.scores[i], fresh.scores[i]));
                }
                if (inc.weights[i] - fresh.weights[i]).abs() > 1e-4 {
                    return Err(format!("weight {i}: {} vs {}", inc.weights[i], fresh.weights[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn stripe_fills_only_owned_columns() {
        let mut rng = Rng::new(2);
        let block = random_block(&mut rng, 40, 6);
        let model = StrongRule::new();
        let grid = CandidateGrid::uniform(6, 2, -1.0, 1.0);
        let mut be = NativeBackend;
        let r = be.scan_batch(
            &block,
            &vec![1.0; 40],
            &vec![0.0; 40],
            &vec![0u32; 40],
            &model,
            &grid,
            (2, 4),
        );
        for f in 0..6 {
            for t in 0..2 {
                let e = r.edges.edge(f, t);
                if (2..4).contains(&f) {
                    // owned columns are real accumulations (non-zero w.h.p.)
                    continue;
                }
                assert_eq!(e, 0.0, "unowned column f={f} populated");
            }
        }
        // scalars cover the whole batch regardless of stripe
        assert_eq!(r.edges.count, 40);
        assert!((r.edges.sum_w - 40.0).abs() < 1e-6);
    }

    #[test]
    fn scratch_reuse_across_batches_matches_per_batch_allocation() {
        // the zero-allocation path (one BatchResult reused, edges
        // accumulated in place) equals scan_batch-per-batch + merge
        let mut rng = Rng::new(3);
        let block = random_block(&mut rng, 200, 5);
        let model = random_model(&mut rng, 5, 4);
        let grid = CandidateGrid::uniform(5, 3, -1.2, 1.2);
        let mut be = NativeBackend;

        let mut merged = EdgeMatrix::zeros(5, 3);
        let mut reused = BatchResult::zeros(5, 3);
        reused.reset(5, 3);
        let mut off = 0;
        for chunk in block.chunks(64) {
            let w_ref = vec![1.0f32; chunk.n];
            let s_ref = vec![0.0f32; chunk.n];
            let l_ref = vec![0u32; chunk.n];
            let r = be.scan_batch(&chunk, &w_ref, &s_ref, &l_ref, &model, &grid, (0, 5));
            merged.merge(&r.edges);
            be.scan_batch_into(
                &chunk, None, &w_ref, &s_ref, &l_ref, &model, &grid, (0, 5), &mut reused,
            );
            // per-batch vectors hold exactly this batch
            assert_eq!(reused.scores.len(), chunk.n);
            assert_eq!(reused.scores, r.scores);
            assert_eq!(reused.weights, r.weights);
            off += chunk.n;
        }
        assert_eq!(off, 200);
        assert_eq!(merged.edges, reused.edges.edges, "bit-identical");
        assert_eq!(merged.count, reused.edges.count);
        assert_eq!(merged.sum_w.to_bits(), reused.edges.sum_w.to_bits());
    }

    /// Inject boundary values: snap some features to exact grid thresholds
    /// and set a few to ±∞.
    fn inject_boundary_values(rng: &mut Rng, block: &mut DataBlock, grid: &CandidateGrid) {
        let n = block.n;
        let f = block.f;
        for _ in 0..(n * f / 4).max(1) {
            let i = rng.below(n as u64) as usize;
            let j = rng.below(f as u64) as usize;
            block.features[i * f + j] = match rng.below(4) {
                0 => f32::INFINITY,
                1 => f32::NEG_INFINITY,
                _ => grid.row(j)[rng.below(grid.nthr as u64) as usize],
            };
        }
    }

    #[test]
    fn prop_binned_matches_native_and_bruteforce() {
        // the tentpole equivalence: binned == rows == brute force over
        // random blocks/grids/stripes, including values exactly equal to
        // thresholds and ±∞ at the bin boundaries
        prop_check("binned == native == bruteforce", 30, |rng| {
            let n = gen::size(rng, 1, 700); // spans 1–2 BIN_CHUNK chunks
            let f = gen::size(rng, 1, 9);
            let nthr = gen::size(rng, 1, 6);
            let mut block = random_block(rng, n, f);
            let grid = CandidateGrid::uniform(f, nthr, -2.0, 2.0);
            inject_boundary_values(rng, &mut block, &grid);
            let fs = rng.below(f as u64) as usize;
            let fe = fs + 1 + rng.below((f - fs) as u64) as usize;
            let threads = 1 + rng.below(4) as usize;

            let w_ref: Vec<f32> = gen::skewed_weights(rng, n, 2.0);
            let s_ref = vec![0.0f32; n];
            let l_ref = vec![0u32; n];
            let model = StrongRule::new(); // empty → weights == w_ref exactly

            let mut rows = NativeBackend;
            let a = rows.scan_batch(&block, &w_ref, &s_ref, &l_ref, &model, &grid, (fs, fe));

            let bins = bins_for(&block, &grid, (fs, fe));
            let mut binned = BinnedBackend::new(threads);
            let mut b = BatchResult::zeros(f, nthr);
            binned.scan_batch_into(
                &block,
                Some(&bins),
                &w_ref,
                &s_ref,
                &l_ref,
                &model,
                &grid,
                (fs, fe),
                &mut b,
            );

            let brute = edges_bruteforce(&block, &w_ref, &grid);
            for ff in fs..fe {
                for t in 0..nthr {
                    let ea = a.edges.edge(ff, t);
                    let eb = b.edges.edge(ff, t);
                    let ec = brute.edge(ff, t);
                    if (ea - eb).abs() > 1e-9 * (1.0 + ea.abs()) {
                        return Err(format!(
                            "binned vs rows f={ff} t={t}: {eb} vs {ea} (n={n} thr={threads})"
                        ));
                    }
                    if (ea - ec).abs() > 1e-6 * (1.0 + ec.abs()) {
                        return Err(format!("rows vs brute f={ff} t={t}: {ea} vs {ec}"));
                    }
                }
            }
            if a.edges.sum_w.to_bits() != b.edges.sum_w.to_bits()
                || a.edges.sum_w2.to_bits() != b.edges.sum_w2.to_bits()
                || a.edges.count != b.edges.count
            {
                return Err("stopping scalars diverged".into());
            }
            // single-chunk batches are bit-identical, not just close
            if n <= BIN_CHUNK {
                for ff in fs..fe {
                    for t in 0..nthr {
                        if a.edges.edge(ff, t).to_bits() != b.edges.edge(ff, t).to_bits() {
                            return Err(format!("single-chunk bit mismatch f={ff} t={t}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn binned_identical_across_thread_counts() {
        // the determinism property: the merge order is fixed by chunk
        // boundaries, so --scan-threads ∈ {1, 2, 7} give the identical
        // EdgeMatrix, bit for bit
        let mut rng = Rng::new(7);
        let n = 1500; // 3 chunks
        let f = 6;
        let nthr = 4;
        let block = random_block(&mut rng, n, f);
        let grid = CandidateGrid::uniform(f, nthr, -1.5, 1.5);
        let model = random_model(&mut rng, f, 3);
        let w_ref = gen::skewed_weights(&mut rng, n, 3.0);
        let s_ref = vec![0.0f32; n];
        let l_ref = vec![0u32; n];
        let bins = bins_for(&block, &grid, (0, f));

        let mut results = Vec::new();
        for threads in [1usize, 2, 7] {
            let mut be = BinnedBackend::new(threads);
            let mut out = BatchResult::zeros(f, nthr);
            be.scan_batch_into(
                &block,
                Some(&bins),
                &w_ref,
                &s_ref,
                &l_ref,
                &model,
                &grid,
                (0, f),
                &mut out,
            );
            results.push(out.edges);
        }
        for other in &results[1..] {
            assert_eq!(results[0].edges, other.edges, "edges differ across thread counts");
            assert_eq!(results[0].sum_w.to_bits(), other.sum_w.to_bits());
            assert_eq!(results[0].sum_w2.to_bits(), other.sum_w2.to_bits());
            assert_eq!(results[0].count, other.count);
        }
    }

    #[test]
    fn binned_without_bins_falls_back_to_row_path() {
        let mut rng = Rng::new(9);
        let block = random_block(&mut rng, 80, 4);
        let grid = CandidateGrid::uniform(4, 3, -1.0, 1.0);
        let model = random_model(&mut rng, 4, 2);
        let w_ref = vec![1.0f32; 80];
        let s_ref = vec![0.0f32; 80];
        let l_ref = vec![0u32; 80];
        let mut rows = NativeBackend;
        let a = rows.scan_batch(&block, &w_ref, &s_ref, &l_ref, &model, &grid, (0, 4));
        let mut binned = BinnedBackend::new(2);
        let b = binned.scan_batch(&block, &w_ref, &s_ref, &l_ref, &model, &grid, (0, 4));
        assert_eq!(a.edges.edges, b.edges.edges);
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn wants_bins_flags() {
        assert!(!NativeBackend.wants_bins());
        assert!(BinnedBackend::new(1).wants_bins());
        assert_eq!(BinnedBackend::new(3).name(), "binned");
    }

    #[test]
    #[should_panic(expected = "scan-threads")]
    fn binned_rejects_zero_threads() {
        BinnedBackend::new(0);
    }

    #[test]
    fn default_constructor_is_scalar() {
        // `new` must stay the scalar engine in every build flavor — the
        // default (`--scan-simd auto` without the feature) path is the
        // pre-SIMD behavior, byte for byte
        assert_eq!(BinnedBackend::new(2).kernel(), "scalar");
        assert_eq!(BinnedBackend::with_simd(2, false).kernel(), "scalar");
    }

    #[cfg(not(feature = "simd"))]
    #[test]
    #[should_panic(expected = "compiled out")]
    fn with_simd_panics_when_compiled_out() {
        BinnedBackend::with_simd(1, true);
    }

    #[cfg(not(feature = "simd"))]
    #[test]
    fn lane_kernel_reports_compiled_out() {
        assert_eq!(lane_kernel(), "compiled-out");
    }

    #[cfg(feature = "simd")]
    #[test]
    fn with_simd_reports_active_lane_kernel() {
        let k = BinnedBackend::with_simd(1, true).kernel();
        assert!(["avx2", "portable"].contains(&k), "{k}");
        assert_eq!(lane_kernel(), k);
    }

    #[cfg(feature = "simd")]
    #[test]
    fn simd_backend_bit_identical_to_scalar() {
        // compact in-crate check (the full battery lives in
        // tests/scan_differential.rs): same batch through both kernels,
        // multi-chunk with a ragged tail, edges and scalars bitwise equal
        let mut rng = Rng::new(21);
        let n = 2 * BIN_CHUNK + 37;
        let (f, nthr) = (5, 6);
        let mut block = random_block(&mut rng, n, f);
        let grid = CandidateGrid::uniform(f, nthr, -1.5, 1.5);
        inject_boundary_values(&mut rng, &mut block, &grid);
        let w_ref = gen::skewed_weights(&mut rng, n, 3.0);
        let bins = bins_for(&block, &grid, (0, f));
        let mut scalar = EdgeMatrix::zeros(f, nthr);
        BinnedBackend::with_simd(2, false)
            .accumulate_batch(&bins, &w_ref, &block.labels, nthr, (0, f), &mut scalar);
        let mut lanes = EdgeMatrix::zeros(f, nthr);
        BinnedBackend::with_simd(2, true)
            .accumulate_batch(&bins, &w_ref, &block.labels, nthr, (0, f), &mut lanes);
        assert_eq!(scalar.edges, lanes.edges, "edges diverged bitwise");
        assert_eq!(scalar.sum_w.to_bits(), lanes.sum_w.to_bits());
        assert_eq!(scalar.sum_w2.to_bits(), lanes.sum_w2.to_bits());
        assert_eq!(scalar.count, lanes.count);
    }
}
