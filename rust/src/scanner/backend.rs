//! Scan-batch compute backends.
//!
//! One batch step = (re)score a block of examples under the current model,
//! refresh their weights incrementally, and accumulate candidate edges —
//! the computation AOT-lowered in `python/compile/model.py::scan_batch`.
//! [`NativeBackend`] is the pure-Rust mirror (bit-compatible semantics);
//! the PJRT-backed backends live in `crate::runtime` and are selected via
//! `config::Backend` (ablation A4).

use crate::boosting::{edges::accumulate_edges_stripe, CandidateGrid, EdgeMatrix};
use crate::data::DataBlock;
use crate::model::StrongRule;

/// Result of one scan batch.
#[derive(Debug, Clone)]
pub struct BatchResult {
    /// per-example strong-rule score under the *current* model
    pub scores: Vec<f32>,
    /// per-example refreshed weight
    pub weights: Vec<f32>,
    /// candidate edge contributions of this batch (full grid width; only
    /// the stripe columns are required to be filled)
    pub edges: EdgeMatrix,
}

/// A compute backend for scan batches.
pub trait ScanBackend: Send {
    /// Process one batch.
    ///
    /// * `block` — the examples (full feature width).
    /// * `w_ref`, `score_ref` — the cached `(w_l, H_l(x))` pair per example:
    ///   weights satisfy `w = w_ref · exp(−y·(H(x) − score_ref))` for ANY
    ///   consistent reference pair, which is what makes the incremental
    ///   update exact (§4.1).
    /// * `model_len_ref` — length of the model that produced `score_ref`
    ///   (lets the native path evaluate only the new suffix).
    /// * `grid` — full candidate grid; `stripe` — the `[start, end)` range
    ///   of features this worker owns.
    fn scan_batch(
        &mut self,
        block: &DataBlock,
        w_ref: &[f32],
        score_ref: &[f32],
        model_len_ref: &[u32],
        model: &StrongRule,
        grid: &CandidateGrid,
        stripe: (usize, usize),
    ) -> BatchResult;

    fn name(&self) -> &'static str;
}

/// Pure-Rust backend: incremental suffix scoring + striped edge pass.
#[derive(Debug, Default)]
pub struct NativeBackend;

impl ScanBackend for NativeBackend {
    fn scan_batch(
        &mut self,
        block: &DataBlock,
        w_ref: &[f32],
        score_ref: &[f32],
        model_len_ref: &[u32],
        model: &StrongRule,
        grid: &CandidateGrid,
        stripe: (usize, usize),
    ) -> BatchResult {
        let n = block.n;
        debug_assert_eq!(w_ref.len(), n);
        debug_assert_eq!(score_ref.len(), n);
        debug_assert_eq!(model_len_ref.len(), n);
        let mut scores = Vec::with_capacity(n);
        let mut weights = Vec::with_capacity(n);
        for i in 0..n {
            let row = block.row(i);
            // incremental: only the suffix the reference hasn't seen
            let delta = model.score_suffix(row, model_len_ref[i] as usize);
            let score = score_ref[i] + delta;
            let w = w_ref[i] * (-(block.label(i)) * delta).exp();
            scores.push(score);
            weights.push(w);
        }
        let mut edges = EdgeMatrix::zeros(grid.f, grid.nthr);
        accumulate_edges_stripe(block, &weights, grid, stripe, &mut edges);
        BatchResult {
            scores,
            weights,
            edges,
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Stump;
    use crate::util::prop::{gen, prop_check};
    use crate::util::rng::Rng;

    fn random_block(rng: &mut Rng, n: usize, f: usize) -> DataBlock {
        DataBlock::new(
            n,
            f,
            gen::normal_vec(rng, n * f),
            gen::labels(rng, n, 0.4),
        )
    }

    fn random_model(rng: &mut Rng, f: usize, t: usize) -> StrongRule {
        let mut m = StrongRule::new();
        for _ in 0..t {
            m.push(
                Stump::new(
                    rng.below(f as u64) as u32,
                    rng.gauss() as f32 * 0.5,
                    if rng.bernoulli(0.5) { 1.0 } else { -1.0 },
                ),
                0.05 + rng.f64() as f32 * 0.3,
            );
        }
        m
    }

    #[test]
    fn fresh_reference_matches_direct_scoring() {
        let mut rng = Rng::new(1);
        let block = random_block(&mut rng, 50, 8);
        let model = random_model(&mut rng, 8, 5);
        let grid = CandidateGrid::uniform(8, 3, -1.5, 1.5);
        let w_ref = vec![1.0f32; 50];
        let score_ref = vec![0.0f32; 50];
        let len_ref = vec![0u32; 50];
        let mut be = NativeBackend;
        let r = be.scan_batch(&block, &w_ref, &score_ref, &len_ref, &model, &grid, (0, 8));
        for i in 0..50 {
            let want_score = model.score(block.row(i));
            assert!((r.scores[i] - want_score).abs() < 1e-5);
            let want_w = (-(block.label(i)) * want_score).exp();
            assert!((r.weights[i] - want_w).abs() < 1e-4);
        }
    }

    #[test]
    fn prop_incremental_equals_fresh() {
        // updating from a cached mid-model state gives identical weights
        // to scoring from scratch — the §4.1 invariant.
        prop_check("incremental == fresh", 30, |rng| {
            let n = gen::size(rng, 5, 60);
            let f = gen::size(rng, 2, 8);
            let block = random_block(rng, n, f);
            let model = random_model(rng, f, 6);
            let grid = CandidateGrid::uniform(f, 2, -1.0, 1.0);
            let mut be = NativeBackend;

            // fresh path
            let fresh = be.scan_batch(
                &block,
                &vec![1.0; n],
                &vec![0.0; n],
                &vec![0u32; n],
                &model,
                &grid,
                (0, f),
            );
            // cached path: reference = model prefix of length 3
            let mut prefix = StrongRule::new();
            for t in 0..3 {
                prefix.push(model.stumps()[t], model.alphas()[t]);
            }
            let mid = be.scan_batch(
                &block,
                &vec![1.0; n],
                &vec![0.0; n],
                &vec![0u32; n],
                &prefix,
                &grid,
                (0, f),
            );
            let inc = be.scan_batch(
                &block,
                &mid.weights,
                &mid.scores,
                &vec![3u32; n],
                &model,
                &grid,
                (0, f),
            );
            for i in 0..n {
                if (inc.scores[i] - fresh.scores[i]).abs() > 1e-4 {
                    return Err(format!("score {i}: {} vs {}", inc.scores[i], fresh.scores[i]));
                }
                if (inc.weights[i] - fresh.weights[i]).abs() > 1e-4 {
                    return Err(format!("weight {i}: {} vs {}", inc.weights[i], fresh.weights[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn stripe_fills_only_owned_columns() {
        let mut rng = Rng::new(2);
        let block = random_block(&mut rng, 40, 6);
        let model = StrongRule::new();
        let grid = CandidateGrid::uniform(6, 2, -1.0, 1.0);
        let mut be = NativeBackend;
        let r = be.scan_batch(
            &block,
            &vec![1.0; 40],
            &vec![0.0; 40],
            &vec![0u32; 40],
            &model,
            &grid,
            (2, 4),
        );
        for f in 0..6 {
            for t in 0..2 {
                let e = r.edges.edge(f, t);
                if (2..4).contains(&f) {
                    // owned columns are real accumulations (non-zero w.h.p.)
                    continue;
                }
                assert_eq!(e, 0.0, "unowned column f={f} populated");
            }
        }
        // scalars cover the whole batch regardless of stripe
        assert_eq!(r.edges.count, 40);
        assert!((r.edges.sum_w - 40.0).abs() < 1e-6);
    }
}
