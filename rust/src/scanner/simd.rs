//! Lane-widened bucket-accumulation kernels for the binned scan engine
//! (`--scan-simd`, DESIGN.md §14). Compiled only with `--features simd`;
//! the default build carries the scalar loop alone and is byte-identical
//! to the pre-SIMD engine.
//!
//! # Why the lane kernels are bit-identical to the scalar loop
//!
//! The scalar accumulation is a scatter: `hist[bin[i]] += u[i]`, so each
//! histogram slot receives the `u` of its matching examples in ascending
//! example order. The lane kernels vectorize across **histogram slots**,
//! not across examples: each f64 lane owns one slot, examples stream in
//! the same ascending order, and every example contributes `u[i]` to the
//! matching lane and an exact `+0.0` to the rest. The contribution is a
//! bitwise select (mask AND — never a multiply), so ±∞, NaN and
//! subnormal `u` survive unchanged in the matching lane. Adding `+0.0`
//! is the f64 identity on every value a lane accumulator can hold: the
//! accumulator starts at `+0.0` and can never become `-0.0` (under
//! round-to-nearest a sum is `-0.0` only when both operands are `-0.0`).
//! The per-slot f64 summation tree is therefore *the same tree* the
//! scalar loop builds — not merely a fixed alternative order — so
//! `scalar == portable == avx2`, bit for bit, for every input, ragged
//! batch tail, chunking, and thread count.
//!
//! Each kernel requires the destination slots to start at `+0.0` for the
//! strict scalar-equality claim; the engine's per-chunk partials always
//! do (they are zeroed on resize each batch).

/// f64 lanes per vector register (AVX2: 256 bits / 64).
pub const SLOT_LANES: usize = 4;

/// Name of the lane kernel the runtime dispatch selects on this CPU:
/// `"avx2"` when detected, else the `"portable"` fallback.
pub fn active_lane_kernel() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return "avx2";
        }
    }
    "portable"
}

/// Accumulate `hist[colbins[i]] += u[i]` for `i ∈ [lo, hi)` over one
/// column's `nthr + 1` histogram slots with the best available lane
/// kernel (feature-detection ladder: avx2 → portable).
#[inline]
pub fn accumulate_column(colbins: &[u8], u: &[f64], lo: usize, hi: usize, hist: &mut [f64]) {
    debug_assert!(hi <= colbins.len() && hi <= u.len());
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: gated on runtime AVX2 detection.
            unsafe { accumulate_column_avx2(colbins, u, lo, hi, hist) };
            return;
        }
    }
    accumulate_column_portable(colbins, u, lo, hi, hist);
}

/// Portable lane kernel: [`SLOT_LANES`]-slot groups held in register
/// accumulators, one pass over the examples per group, branch-free
/// bitwise select per lane. Public (like the avx2 kernel) so the
/// differential battery can pin `portable == avx2 == scalar` on every
/// CPU, not just whichever the ladder picks.
pub fn accumulate_column_portable(
    colbins: &[u8],
    u: &[f64],
    lo: usize,
    hi: usize,
    hist: &mut [f64],
) {
    let nslots = hist.len();
    let mut base = 0usize;
    while base < nslots {
        let mut acc = [0.0f64; SLOT_LANES];
        for i in lo..hi {
            let b = colbins[i] as usize;
            let bits = u[i].to_bits();
            for (l, a) in acc.iter_mut().enumerate() {
                // all-ones mask iff this lane's slot matches the bin
                let mask = ((b == base + l) as u64).wrapping_neg();
                *a += f64::from_bits(bits & mask);
            }
        }
        // lanes fold into their slots in ascending slot order; padding
        // lanes past the last slot never matched any bin and are dropped
        for (l, &a) in acc.iter().enumerate().take(nslots - base) {
            hist[base + l] += a;
        }
        base += SLOT_LANES;
    }
}

/// AVX2 specialization: up to four slot-groups (16 slots) per pass over
/// the examples, all accumulators register-resident. Same select, same
/// per-slot operation order as the portable kernel, hence bit-identical.
///
/// # Safety
/// The CPU must support AVX2 (`is_x86_feature_detected!("avx2")`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn accumulate_column_avx2(
    colbins: &[u8],
    u: &[f64],
    lo: usize,
    hi: usize,
    hist: &mut [f64],
) {
    let nslots = hist.len();
    let mut base = 0usize;
    while base < nslots {
        let groups = (nslots - base).div_ceil(SLOT_LANES).min(4);
        match groups {
            1 => avx2_pass::<1>(colbins, u, lo, hi, base, hist),
            2 => avx2_pass::<2>(colbins, u, lo, hi, base, hist),
            3 => avx2_pass::<3>(colbins, u, lo, hi, base, hist),
            _ => avx2_pass::<4>(colbins, u, lo, hi, base, hist),
        }
        base += groups * SLOT_LANES;
    }
}

/// One AVX2 pass: `G` slot-groups starting at slot `base`, every example
/// broadcast-compared against each group's constant slot indices.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn avx2_pass<const G: usize>(
    colbins: &[u8],
    u: &[f64],
    lo: usize,
    hi: usize,
    base: usize,
    hist: &mut [f64],
) {
    use std::arch::x86_64::*;
    let nslots = hist.len();
    let mut idx = [_mm256_setzero_si256(); G];
    let mut acc = [_mm256_setzero_pd(); G];
    for (g, v) in idx.iter_mut().enumerate() {
        let s = (base + g * SLOT_LANES) as i64;
        *v = _mm256_set_epi64x(s + 3, s + 2, s + 1, s);
    }
    for i in lo..hi {
        let b = _mm256_set1_epi64x(colbins[i] as i64);
        let uv = _mm256_set1_pd(u[i]);
        for g in 0..G {
            // lane-select u (bitwise AND with the all-ones/zeros compare
            // mask — non-matching lanes add an exact +0.0)
            let m = _mm256_castsi256_pd(_mm256_cmpeq_epi64(b, idx[g]));
            acc[g] = _mm256_add_pd(acc[g], _mm256_and_pd(m, uv));
        }
    }
    let mut lanes = [0.0f64; SLOT_LANES];
    for g in 0..G {
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc[g]);
        let s = base + g * SLOT_LANES;
        for (l, &v) in lanes.iter().enumerate().take(SLOT_LANES.min(nslots - s)) {
            hist[s + l] += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn scalar(colbins: &[u8], u: &[f64], lo: usize, hi: usize, hist: &mut [f64]) {
        for i in lo..hi {
            hist[colbins[i] as usize] += u[i];
        }
    }

    /// Random u with injected ±∞, NaN, subnormal and -0.0 values.
    fn hostile_u(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n)
            .map(|_| match rng.below(12) {
                0 => f64::INFINITY,
                1 => f64::NEG_INFINITY,
                2 => f64::NAN,
                3 => f64::from_bits(1 + rng.below(100)), // subnormal
                4 => -0.0,
                _ => rng.gauss(),
            })
            .collect()
    }

    #[test]
    fn portable_matches_scalar_bitwise() {
        let mut rng = Rng::new(41);
        // ragged slot counts around the lane width, plus the u8 maximum
        for nslots in [1usize, 2, 3, 4, 5, 7, 8, 9, 16, 17, 19, 256] {
            for n in [0usize, 1, 3, 7, 64, 513] {
                let bins: Vec<u8> = (0..n).map(|_| rng.below(nslots as u64) as u8).collect();
                let u = hostile_u(&mut rng, n);
                let mut a = vec![0.0f64; nslots];
                let mut b = vec![0.0f64; nslots];
                scalar(&bins, &u, 0, n, &mut a);
                accumulate_column_portable(&bins, &u, 0, n, &mut b);
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "nslots={nslots} n={n}");
                }
            }
        }
    }

    #[test]
    fn dispatch_and_avx2_match_scalar_bitwise() {
        let mut rng = Rng::new(43);
        for nslots in [3usize, 5, 9, 17, 33, 256] {
            let n = 700; // crosses a lane-pass boundary and a ragged tail
            let bins: Vec<u8> = (0..n).map(|_| rng.below(nslots as u64) as u8).collect();
            let u = hostile_u(&mut rng, n);
            let (lo, hi) = (13, n - 5); // sub-range, like a mid-batch chunk
            let mut want = vec![0.0f64; nslots];
            scalar(&bins, &u, lo, hi, &mut want);
            let mut got = vec![0.0f64; nslots];
            accumulate_column(&bins, &u, lo, hi, &mut got);
            for (x, y) in want.iter().zip(&got) {
                assert_eq!(x.to_bits(), y.to_bits(), "dispatch nslots={nslots}");
            }
            #[cfg(target_arch = "x86_64")]
            if std::arch::is_x86_feature_detected!("avx2") {
                let mut got = vec![0.0f64; nslots];
                unsafe { accumulate_column_avx2(&bins, &u, lo, hi, &mut got) };
                for (x, y) in want.iter().zip(&got) {
                    assert_eq!(x.to_bits(), y.to_bits(), "avx2 nslots={nslots}");
                }
            }
        }
    }

    #[test]
    fn active_kernel_is_named() {
        assert!(["avx2", "portable"].contains(&active_lane_kernel()));
    }
}
