//! Lightweight property-based testing (no `proptest` in the offline env).
//!
//! `prop_check` runs a property over many seeded random cases and reports
//! the failing seed so the case is exactly reproducible:
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla_extension rpath of regular
//! //  test targets; the same property runs as a unit test below)
//! use sparrow::util::prop::prop_check;
//! use sparrow::util::rng::Rng;
//! prop_check("sum_commutes", 256, |rng: &mut Rng| {
//!     let a = rng.f64();
//!     let b = rng.f64();
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use crate::util::rng::Rng;

/// Base seed; override with env var `SPARROW_PROP_SEED` to replay a failure.
fn base_seed() -> u64 {
    std::env::var("SPARROW_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FF_EE00)
}

/// Run `prop` over `cases` seeded random cases; panic on the first failure
/// with enough information to replay it.
pub fn prop_check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n\
                 replay: SPARROW_PROP_SEED={base} (case index {case})"
            );
        }
    }
}

/// Generators for common test inputs.
pub mod gen {
    use crate::util::rng::Rng;

    /// Vector of f32 drawn from a standard normal.
    pub fn normal_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.gauss() as f32).collect()
    }

    /// Vector of positive weights with exponential skew up to `e^skew`.
    pub fn skewed_weights(rng: &mut Rng, n: usize, skew: f64) -> Vec<f32> {
        (0..n)
            .map(|_| (-rng.f64() * skew).exp() as f32)
            .collect()
    }

    /// Labels in {-1, +1} with positive rate `p`.
    pub fn labels(rng: &mut Rng, n: usize, p: f64) -> Vec<f32> {
        (0..n)
            .map(|_| if rng.bernoulli(p) { 1.0 } else { -1.0 })
            .collect()
    }

    /// A size in [lo, hi].
    pub fn size(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo + 1) as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check("always_ok", 32, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property 'always_fails'")]
    fn failing_property_panics_with_name() {
        prop_check("always_fails", 8, |_| Err("boom".into()));
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = Rng::new(1);
        let w = gen::skewed_weights(&mut rng, 100, 10.0);
        assert!(w.iter().all(|&x| x > 0.0 && x <= 1.0));
        let y = gen::labels(&mut rng, 100, 0.3);
        assert!(y.iter().all(|&v| v == 1.0 || v == -1.0));
        for _ in 0..100 {
            let s = gen::size(&mut rng, 3, 7);
            assert!((3..=7).contains(&s));
        }
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        prop_check("collect", 8, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        prop_check("collect", 8, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
