//! Minimal JSON writer (no serde in the offline environment).
//!
//! Used for metrics/event output and experiment CSV/JSON dumps. Write-only:
//! all file formats the Rust side *reads* (artifact manifest, config files)
//! are simple `key=value` lines by design.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `Obj` uses a BTreeMap so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics when `self` is not an object.
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{}", n);
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<f32> for Json {
    fn from(n: f32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::from(true).to_string(), "true");
        assert_eq!(Json::from(3i64).to_string(), "3");
        assert_eq!(Json::from(3.5f64).to_string(), "3.5");
        assert_eq!(Json::from("hi").to_string(), "\"hi\"");
    }

    #[test]
    fn string_escapes() {
        assert_eq!(
            Json::from("a\"b\\c\nd").to_string(),
            "\"a\\\"b\\\\c\\nd\""
        );
        assert_eq!(Json::from("\u{1}").to_string(), "\"\\u0001\"");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::from(f64::NAN).to_string(), "null");
        assert_eq!(Json::from(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn arrays_and_objects() {
        let mut o = Json::obj();
        o.set("b", 2u64).set("a", vec![1i64, 2, 3]);
        // BTreeMap => sorted keys, deterministic
        assert_eq!(o.to_string(), "{\"a\":[1,2,3],\"b\":2}");
    }

    #[test]
    fn nested() {
        let mut inner = Json::obj();
        inner.set("x", 1i64);
        let mut o = Json::obj();
        o.set("inner", inner);
        assert_eq!(o.to_string(), "{\"inner\":{\"x\":1}}");
    }

    #[test]
    fn integral_floats_render_as_ints() {
        assert_eq!(Json::from(10.0f64).to_string(), "10");
        assert_eq!(Json::from(-2.0f64).to_string(), "-2");
    }
}
