//! Minimal JSON reader/writer (no serde in the offline environment).
//!
//! Used for metrics/event output, experiment CSV/JSON dumps, and — since
//! the control plane landed (DESIGN.md §10) — for parsing admin/serve RPC
//! requests off the wire. The writer came first; [`Json::parse`] is a
//! small recursive-descent reader that accepts exactly what the writer
//! emits (plus standard JSON it never produces, like `\uXXXX` escapes).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `Obj` uses a BTreeMap so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics when `self` is not an object.
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{}", n);
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

// ---- reading -------------------------------------------------------------

/// Recursion guard for the parser (arrays/objects nested deeper than this
/// are rejected rather than risking the stack).
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Json {
    /// Parse a complete JSON document. Trailing non-whitespace is an error.
    ///
    /// ```
    /// use sparrow::util::json::Json;
    /// let v = Json::parse(r#"{"method":"ping","v":1}"#).unwrap();
    /// assert_eq!(v.get("method").and_then(Json::as_str), Some("ping"));
    /// assert_eq!(v.get("v").and_then(Json::as_u64), Some(1));
    /// ```
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup; `None` when `self` is not an object or the key
    /// is absent.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The value as a float (numbers only).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a non-negative integer (integral numbers only).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// The value as a string slice (strings only).
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as a bool (booleans only).
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The value as an array slice (arrays only).
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Is this JSON `null`?
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}",
                b as char, self.pos
            ))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err("nesting too deep".into());
        }
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            _ => Err(format!("unexpected byte at {}", self.pos)),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            m.insert(key, self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E') | Some(b'+') | Some(b'-')
        ) {
            self.pos += 1;
        }
        let span = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| "bad number".to_string())?;
        span.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {span:?} at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            // surrogate pair: a high surrogate must be
                            // followed by an escaped low surrogate
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                if self.peek() != Some(b'\\') {
                                    return Err("lone surrogate".into());
                                }
                                self.pos += 1;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err("bad surrogate pair".into());
                                }
                                let code =
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(code).ok_or("bad surrogate pair")?
                            } else {
                                char::from_u32(hi).ok_or("lone surrogate")?
                            };
                            out.push(c);
                            continue; // hex4 advanced past the digits
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(b) if b < 0x20 => {
                    return Err(format!("raw control byte at {}", self.pos))
                }
                Some(_) => {
                    // consume one full UTF-8 scalar (input is &str, so the
                    // byte stream is valid UTF-8 by construction)
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    /// Read exactly four hex digits (after `\u`), leaving `pos` past them.
    fn hex4(&mut self) -> Result<u32, String> {
        let end = self.pos + 4;
        if end > self.bytes.len() {
            return Err("truncated \\u escape".into());
        }
        let span = std::str::from_utf8(&self.bytes[self.pos..end])
            .map_err(|_| "bad \\u escape".to_string())?;
        let v = u32::from_str_radix(span, 16).map_err(|_| "bad \\u escape".to_string())?;
        self.pos = end;
        Ok(v)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<f32> for Json {
    fn from(n: f32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::from(true).to_string(), "true");
        assert_eq!(Json::from(3i64).to_string(), "3");
        assert_eq!(Json::from(3.5f64).to_string(), "3.5");
        assert_eq!(Json::from("hi").to_string(), "\"hi\"");
    }

    #[test]
    fn string_escapes() {
        assert_eq!(
            Json::from("a\"b\\c\nd").to_string(),
            "\"a\\\"b\\\\c\\nd\""
        );
        assert_eq!(Json::from("\u{1}").to_string(), "\"\\u0001\"");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::from(f64::NAN).to_string(), "null");
        assert_eq!(Json::from(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn arrays_and_objects() {
        let mut o = Json::obj();
        o.set("b", 2u64).set("a", vec![1i64, 2, 3]);
        // BTreeMap => sorted keys, deterministic
        assert_eq!(o.to_string(), "{\"a\":[1,2,3],\"b\":2}");
    }

    #[test]
    fn nested() {
        let mut inner = Json::obj();
        inner.set("x", 1i64);
        let mut o = Json::obj();
        o.set("inner", inner);
        assert_eq!(o.to_string(), "{\"inner\":{\"x\":1}}");
    }

    #[test]
    fn integral_floats_render_as_ints() {
        assert_eq!(Json::from(10.0f64).to_string(), "10");
        assert_eq!(Json::from(-2.0f64).to_string(), "-2");
    }

    // ---- parser ----------------------------------------------------------

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3").unwrap(), Json::Num(3.0));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_structures_and_accessors() {
        let v = Json::parse(r#"{"a":[1,2,3],"b":{"c":"x"},"d":null}"#).unwrap();
        assert_eq!(v.get("a").and_then(Json::as_arr).unwrap().len(), 3);
        assert_eq!(
            v.get("b").and_then(|b| b.get("c")).and_then(Json::as_str),
            Some("x")
        );
        assert!(v.get("d").unwrap().is_null());
        assert!(v.get("missing").is_none());
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_u64(), Some(2));
    }

    #[test]
    fn parse_string_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndAé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndAé"));
        // surrogate pair → astral scalar
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
        assert!(Json::parse(r#""\ud83d""#).is_err(), "lone surrogate");
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in [
            "", "{", "}", "[1,", "{\"a\":}", "nul", "1 2", "{\"a\" 1}", "\"open",
            "{'a':1}", "[1,]",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn parse_rejects_deep_nesting() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(Json::parse(&deep).is_err());
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::parse("[ ]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{ }").unwrap(), Json::obj());
    }

    #[test]
    fn prop_writer_parser_roundtrip() {
        // Anything the writer emits, the parser reads back exactly.
        use crate::util::prop::prop_check;
        use crate::util::rng::Rng;

        fn random_json(rng: &mut Rng, depth: usize) -> Json {
            match if depth > 3 { rng.below(5) } else { rng.below(7) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bernoulli(0.5)),
                2 => Json::Num((rng.below(2_000_001) as f64 - 1e6) / 64.0),
                3 | 4 => {
                    let s: String = (0..rng.below(12))
                        .map(|_| match rng.below(6) {
                            0 => '"',
                            1 => '\\',
                            2 => '\n',
                            3 => 'é',
                            _ => (b'a' + rng.below(26) as u8) as char,
                        })
                        .collect();
                    Json::Str(s)
                }
                5 => Json::Arr(
                    (0..rng.below(4))
                        .map(|_| random_json(rng, depth + 1))
                        .collect(),
                ),
                _ => {
                    let mut o = Json::obj();
                    for k in 0..rng.below(4) {
                        o.set(&format!("k{k}"), random_json(rng, depth + 1));
                    }
                    o
                }
            }
        }

        prop_check("json writer/parser roundtrip", 128, |rng| {
            let v = random_json(rng, 0);
            let text = v.to_string();
            let back = Json::parse(&text).map_err(|e| format!("{e} in {text}"))?;
            if back != v {
                return Err(format!("{back:?} != {v:?} (text {text})"));
            }
            Ok(())
        });
    }
}
