//! In-tree micro/macro benchmark harness (no `criterion` offline).
//!
//! Benches under `rust/benches/` are `harness = false` binaries that use
//! [`BenchRunner`] for warmup + repeated timing with median/MAD reporting,
//! and [`Table`] for printing paper-style result tables.

use std::time::{Duration, Instant};

/// Timing statistics over repeated runs.
#[derive(Debug, Clone)]
pub struct Stats {
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub mean: Duration,
    pub runs: usize,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort();
        let runs = samples.len();
        let median = samples[runs / 2];
        let mean = samples.iter().sum::<Duration>() / runs as u32;
        Stats {
            median,
            min: samples[0],
            max: samples[runs - 1],
            mean,
            runs,
        }
    }
}

/// Repeated-measurement runner with warmup.
pub struct BenchRunner {
    pub warmup: usize,
    pub runs: usize,
    pub min_time: Duration,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner {
            warmup: 1,
            runs: 5,
            min_time: Duration::from_millis(50),
        }
    }
}

impl BenchRunner {
    pub fn quick() -> Self {
        BenchRunner {
            warmup: 1,
            runs: 3,
            min_time: Duration::from_millis(10),
        }
    }

    /// Benchmark `f`, returning timing stats. `f` is called once per run.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.runs);
        for _ in 0..self.runs {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        let stats = Stats::from_samples(samples);
        println!(
            "{name}: median {:?} (min {:?}, max {:?}, {} runs)",
            stats.median, stats.min, stats.max, stats.runs
        );
        stats
    }

    /// Benchmark with an inner-iteration count so very fast ops are measurable.
    /// Reports per-op time.
    pub fn bench_n<T>(&self, name: &str, iters: usize, mut f: impl FnMut() -> T) -> Duration {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut best = Duration::MAX;
        for _ in 0..self.runs {
            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            let per_op = t0.elapsed() / iters as u32;
            best = best.min(per_op);
        }
        println!("{name}: {:?}/op (best of {}, {} iters)", best, self.runs, iters);
        best
    }
}

/// Paper-style fixed-width result table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", line(row));
        }
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a duration as fractional seconds for tables.
pub fn secs(d: Duration) -> String {
    format!("{:.3}", d.as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_median() {
        let s = Stats::from_samples(vec![
            Duration::from_millis(3),
            Duration::from_millis(1),
            Duration::from_millis(2),
        ]);
        assert_eq!(s.median, Duration::from_millis(2));
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.max, Duration::from_millis(3));
        assert_eq!(s.runs, 3);
    }

    #[test]
    fn bench_runs() {
        let r = BenchRunner::quick();
        let mut count = 0;
        let s = r.bench("noop", || {
            count += 1;
            count
        });
        assert_eq!(s.runs, 3);
        assert_eq!(count, 4); // 1 warmup + 3 runs
    }

    #[test]
    fn table_csv() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic]
    fn table_row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }
}
