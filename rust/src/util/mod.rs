//! Shared infrastructure: PRNG, JSON writer, CLI parsing, bench harness,
//! and property-testing helpers.
//!
//! The offline build environment provides no `rand`/`serde`/`clap`/
//! `criterion`/`proptest`; these small, focused replacements keep the rest
//! of the codebase idiomatic.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
