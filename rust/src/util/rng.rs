//! Deterministic PRNG (xoshiro256++ seeded via SplitMix64).
//!
//! The offline build environment has no `rand` crate; this is a small,
//! well-known generator that makes every experiment in the repo seedable
//! and exactly reproducible (`--seed` flows from config into every
//! stochastic component: data synthesis, sampling, network jitter).

/// xoshiro256++ generator. Not cryptographic; excellent statistical quality
/// for simulation workloads.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/consecutive seeds decorrelate.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent stream (for per-worker / per-component rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn gauss(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                return -u.ln() / lambda;
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// k distinct indices sampled uniformly from 0..n (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // partial Fisher-Yates over an index vector
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(42);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(12);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }
}
