//! Minimal CLI argument parser (no `clap` in the offline environment).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! subcommands, and typed getters with defaults. Unknown-flag detection is
//! the caller's responsibility via [`Args::finish`].

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        // first non-flag token is the subcommand
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.values.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.values
                        .insert(stripped.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                // stray positional after flags — treat as error-worthy leftover
                out.flags.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get_u64(key, default as u64) as usize
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    /// All keys that were provided but never queried — catches typos.
    pub fn finish(&self) -> Result<(), String> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&str> = self
            .values
            .keys()
            .map(|s| s.as_str())
            .chain(self.flags.iter().map(|s| s.as_str()))
            .filter(|k| !consumed.iter().any(|c| c == k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown arguments: {}", unknown.join(", ")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_values() {
        let a = parse("train --workers 4 --gap=0.05");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get_u64("workers", 1), 4);
        assert!((a.get_f64("gap", 0.0) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn defaults() {
        let a = parse("train");
        assert_eq!(a.get_u64("workers", 7), 7);
        assert_eq!(a.get_or("name", "x"), "x");
    }

    #[test]
    fn flags() {
        let a = parse("run --verbose --n 3");
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
        assert_eq!(a.get_u64("n", 0), 3);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --n 3 --fast");
        assert!(a.has_flag("fast"));
    }

    #[test]
    fn negative_number_value() {
        // `--x -3` : "-3" does not start with "--", so it is a value
        let a = parse("cmd --x -3");
        assert_eq!(a.get_f64("x", 0.0), -3.0);
    }

    #[test]
    fn finish_flags_unknown() {
        let a = parse("cmd --known 1 --typo 2");
        let _ = a.get("known");
        let err = a.finish().unwrap_err();
        assert!(err.contains("typo"), "{err}");
    }

    #[test]
    fn finish_ok_when_all_consumed() {
        let a = parse("cmd --k 1 --flag");
        let _ = a.get("k");
        let _ = a.has_flag("flag");
        assert!(a.finish().is_ok());
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--x 1");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get_u64("x", 0), 1);
    }
}
