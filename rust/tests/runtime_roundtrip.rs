//! Integration: AOT artifacts (L1 Pallas + L2 JAX, lowered to HLO text)
//! executed through PJRT agree with the native Rust backend bit-for-bit
//! (within f32 tolerance) — the cross-layer correctness contract.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use std::path::Path;

use sparrow::boosting::CandidateGrid;
use sparrow::data::DataBlock;
use sparrow::model::{StrongRule, Stump};
use sparrow::runtime::{Manifest, XlaScanBackend};
use sparrow::scanner::{NativeBackend, ScanBackend};
use sparrow::util::rng::Rng;

const F: usize = 32;
const NT: usize = 4;
const B: usize = 128;

fn manifest() -> Option<Manifest> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP runtime tests: {e}");
            None
        }
    }
}

fn load(pallas: bool) -> Option<XlaScanBackend> {
    let m = manifest()?;
    let spec = m.find_scan(pallas, F, NT).expect("small artifact missing");
    Some(XlaScanBackend::load(&m, spec, pallas).expect("compile artifact"))
}

fn random_inputs(seed: u64, n: usize) -> (DataBlock, Vec<f32>, Vec<f32>, Vec<u32>) {
    let mut rng = Rng::new(seed);
    let mut block = DataBlock::empty(F);
    let mut w = Vec::new();
    let mut s = Vec::new();
    for _ in 0..n {
        let row: Vec<f32> = (0..F).map(|_| rng.gauss() as f32).collect();
        let y = if rng.bernoulli(0.4) { 1.0 } else { -1.0 };
        block.push(&row, y);
        w.push((-rng.f64() * 2.0).exp() as f32);
        s.push(rng.gauss() as f32 * 0.5);
    }
    let l = vec![0u32; n];
    (block, w, s, l)
}

fn random_model(seed: u64, t: usize) -> StrongRule {
    let mut rng = Rng::new(seed);
    let mut m = StrongRule::new();
    for _ in 0..t {
        m.push(
            Stump::new(
                rng.below(F as u64) as u32,
                rng.gauss() as f32 * 0.5,
                if rng.bernoulli(0.5) { 1.0 } else { -1.0 },
            ),
            0.05 + rng.f64() as f32 * 0.4,
        );
    }
    m
}

fn compare_backends(xla: &mut dyn ScanBackend, seed: u64, n: usize, t: usize) {
    let (block, w, s, l) = random_inputs(seed, n);
    // reference pair must be consistent for the full-rescore path:
    // use len_ref = 0 with score_ref = 0 ... but we want to exercise
    // non-trivial references too, so give native the same (w, s, 0) refs.
    let zeros = vec![0f32; n];
    let _ = s;
    let model = random_model(seed ^ 7, t);
    let grid = CandidateGrid::uniform(F, NT, -1.5, 1.5);

    let mut native = NativeBackend;
    let want = native.scan_batch(&block, &w, &zeros, &l, &model, &grid, (0, F));
    let got = xla.scan_batch(&block, &w, &zeros, &l, &model, &grid, (0, F));

    for i in 0..n {
        assert!(
            (got.scores[i] - want.scores[i]).abs() < 1e-4,
            "score {i}: {} vs {}",
            got.scores[i],
            want.scores[i]
        );
        assert!(
            (got.weights[i] - want.weights[i]).abs() < 1e-4 * (1.0 + want.weights[i].abs()),
            "weight {i}: {} vs {}",
            got.weights[i],
            want.weights[i]
        );
    }
    for f in 0..F {
        for tt in 0..NT {
            let a = got.edges.edge(f, tt);
            let b = want.edges.edge(f, tt);
            assert!((a - b).abs() < 1e-2, "edge ({f},{tt}): {a} vs {b}");
        }
    }
    assert!((got.edges.sum_w - want.edges.sum_w).abs() < 1e-2);
    assert!((got.edges.sum_w2 - want.edges.sum_w2).abs() < 1e-2);
}

#[test]
fn pallas_artifact_matches_native_backend() {
    let Some(mut be) = load(true) else { return };
    assert_eq!(be.batch(), B);
    compare_backends(&mut be, 1, B, 5);
}

#[test]
fn jnp_artifact_matches_native_backend() {
    let Some(mut be) = load(false) else { return };
    compare_backends(&mut be, 2, B, 5);
}

#[test]
fn partial_batch_padding_is_neutral() {
    let Some(mut be) = load(true) else { return };
    // n < B: padded rows must not perturb edges/scalars
    compare_backends(&mut be, 3, 77, 3);
}

#[test]
fn empty_model_weights_passthrough() {
    let Some(mut be) = load(true) else { return };
    let (block, w, _, l) = random_inputs(4, 50);
    let zeros = vec![0f32; 50];
    let model = StrongRule::new();
    let grid = CandidateGrid::uniform(F, NT, -1.0, 1.0);
    let got = be.scan_batch(&block, &w, &zeros, &l, &model, &grid, (0, F));
    for i in 0..50 {
        assert!((got.scores[i]).abs() < 1e-6);
        assert!((got.weights[i] - w[i]).abs() < 1e-5);
    }
}

#[test]
fn repeated_execution_stable() {
    // PJRT buffers/literals must not leak state across calls
    let Some(mut be) = load(true) else { return };
    let (block, w, _, l) = random_inputs(5, B);
    let zeros = vec![0f32; B];
    let model = random_model(6, 4);
    let grid = CandidateGrid::uniform(F, NT, -1.0, 1.0);
    let a = be.scan_batch(&block, &w, &zeros, &l, &model, &grid, (0, F));
    let b = be.scan_batch(&block, &w, &zeros, &l, &model, &grid, (0, F));
    assert_eq!(a.scores, b.scores);
    assert_eq!(a.weights, b.weights);
    assert_eq!(a.edges.edges, b.edges.edges);
}
