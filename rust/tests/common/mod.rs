//! Shared fixtures for the cluster-level integration suites.

use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::{Mutex, OnceLock};

use sparrow::data::synth::SynthGen;
use sparrow::data::{DataBlock, SynthConfig};

fn synth_cfg(seed: u64) -> SynthConfig {
    SynthConfig {
        f: 16,
        pos_rate: 0.3,
        informative: 8,
        signal: 0.8,
        flip_rate: 0.02,
        seed,
    }
}

/// Materialize (once per test binary) an `n`-example training store under a
/// suite-specific temp dir, plus the `test_n`-example test block drawn from
/// the same generator stream just past the store prefix (same distribution,
/// disjoint examples).
///
/// Creation is race-free: tests within one binary run on parallel threads,
/// so the store is built under a `OnceLock`, and the file is written to a
/// process-unique temp name and atomically renamed into place — a
/// concurrent or killed writer can never leave a partial store behind for
/// another run to pick up.
pub fn synth_store(suite: &str, seed: u64, n: usize, test_n: usize) -> (PathBuf, DataBlock) {
    let dir = std::env::temp_dir().join(suite);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("train_{seed}_{n}.sprw"));
    // per-path creation guard (not a single global flag, so one binary may
    // materialize stores for several (suite, seed, n) combinations); the
    // lock is held across the write to serialize same-path callers
    static CREATED: OnceLock<Mutex<HashSet<PathBuf>>> = OnceLock::new();
    let mut created = CREATED
        .get_or_init(|| Mutex::new(HashSet::new()))
        .lock()
        .unwrap();
    if created.insert(path.clone()) && !path.exists() {
        let tmp = dir.join(format!(".train_{seed}_{n}.{}.tmp", std::process::id()));
        SynthGen::new(synth_cfg(seed)).write_store(&tmp, n).unwrap();
        // atomic publish; if a concurrent process won the race the rename
        // just replaces its byte-identical file
        std::fs::rename(&tmp, &path).unwrap();
    }
    drop(created);
    // fast-forward a fresh generator past the store prefix so every test
    // shares the identical held-out block
    let mut gen = SynthGen::new(synth_cfg(seed));
    let mut rem = n;
    while rem > 0 {
        let take = rem.min(8192);
        gen.next_block(take);
        rem -= take;
    }
    (path, gen.next_block(test_n))
}
