//! Robustness/property tests: malformed inputs never panic, serialization
//! round-trips under fuzzing, degenerate numerical regimes stay sane.

use sparrow::boosting::{edges_native, CandidateGrid};
use sparrow::data::{binfmt, DataBlock};
use sparrow::model::{StrongRule, Stump};
use sparrow::sampling::n_eff;
use sparrow::stopping::{CandidateStats, LilRule, StoppingRule};
use sparrow::util::prop::{gen, prop_check};
use sparrow::util::rng::Rng;

/// Removes its directory on drop, so the scratch space is cleaned up even
/// when a property fails and `prop_check` panics.
struct ScratchDir(std::path::PathBuf);

impl ScratchDir {
    /// A per-process unique temp dir (pid + wall-clock nonce): concurrent
    /// `cargo test` invocations of this suite can never collide on it.
    fn unique(tag: &str) -> ScratchDir {
        let nonce = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos())
            .unwrap_or(0);
        let dir = std::env::temp_dir().join(format!(
            "sparrow_{tag}_{}_{nonce:x}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).expect("create scratch dir");
        ScratchDir(dir)
    }
}

impl Drop for ScratchDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

#[test]
fn binfmt_rejects_random_garbage_without_panicking() {
    let scratch = ScratchDir::unique("robustness");
    prop_check("garbage files error cleanly", 50, |rng| {
        let path = scratch.0.join(format!("garbage_{}.bin", rng.next_u64()));
        let len = gen::size(rng, 0, 256);
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        std::fs::write(&path, &bytes).map_err(|e| e.to_string())?;
        // must return Err or a header the reader then respects — never panic
        let result = std::panic::catch_unwind(|| {
            if let Ok(mut r) = binfmt::Reader::open(&path) {
                let _ = r.read_block(16, false);
            }
        });
        std::fs::remove_file(&path).ok();
        result.map_err(|_| "panicked on garbage input".to_string())
    });
}

#[test]
fn model_text_fuzz_roundtrip_or_clean_error() {
    prop_check("model text parser total", 100, |rng| {
        // random mutations of a valid serialization
        let mut m = StrongRule::new();
        for t in 0..gen::size(rng, 0, 6) {
            m.push(
                Stump::new(t as u32, rng.gauss() as f32, 1.0),
                0.1 + rng.f32() * 0.5,
            );
        }
        let mut text = m.to_text();
        // flip a random byte half the time
        if rng.bernoulli(0.5) && !text.is_empty() {
            let i = rng.below(text.len() as u64) as usize;
            let mut bytes = text.into_bytes();
            bytes[i] = bytes[i].wrapping_add(1 + rng.below(200) as u8);
            text = String::from_utf8_lossy(&bytes).into_owned();
        }
        let result = std::panic::catch_unwind(|| StrongRule::from_text(&text));
        match result {
            Err(_) => Err("parser panicked".into()),
            Ok(_) => Ok(()), // Ok(model) or Err(msg) both fine
        }
    });
}

#[test]
fn extreme_weights_keep_statistics_finite() {
    // boosting can drive weights to extremes; edge accumulation and n_eff
    // must stay finite and consistent
    prop_check("extreme weight regimes", 30, |rng| {
        let n = gen::size(rng, 2, 64);
        let f = 3;
        let mut block = DataBlock::empty(f);
        let mut w = Vec::new();
        for _ in 0..n {
            let row: Vec<f32> = (0..f).map(|_| rng.gauss() as f32).collect();
            block.push(&row, if rng.bernoulli(0.5) { 1.0 } else { -1.0 });
            // log-uniform across ~60 orders of magnitude
            w.push(10f32.powf((rng.f64() * 60.0 - 30.0) as f32));
        }
        let grid = CandidateGrid::uniform(f, 2, -1.0, 1.0);
        let m = edges_native(&block, &w, &grid);
        if !m.sum_w.is_finite() || !m.sum_w2.is_finite() {
            return Err(format!("non-finite scalars: {} {}", m.sum_w, m.sum_w2));
        }
        for &e in &m.edges {
            if !e.is_finite() {
                return Err("non-finite edge".into());
            }
            if e.abs() > m.sum_w * (1.0 + 1e-9) {
                return Err(format!("edge {} exceeds sum_w {}", e, m.sum_w));
            }
        }
        let ne = n_eff(&w);
        if !(ne.is_finite() && ne >= 0.0 && ne <= n as f64 + 1e-6) {
            return Err(format!("n_eff {ne} out of range"));
        }
        Ok(())
    });
}

#[test]
fn stopping_rule_total_on_degenerate_stats() {
    let rule = LilRule::default();
    for stats in [
        CandidateStats::default(),
        CandidateStats {
            m: f64::MAX / 2.0,
            sum_w: f64::MAX / 2.0,
            sum_w2: f64::MAX / 2.0,
            count: u64::MAX,
        },
        CandidateStats {
            m: -1e300,
            sum_w: 1e-300,
            sum_w2: 1e-300,
            count: 1000,
        },
        CandidateStats {
            m: 0.0,
            sum_w: 0.0,
            sum_w2: 0.0,
            count: 1000,
        },
    ] {
        // must not panic; bound must not be NaN
        let fired = rule.fires(&stats, 0.1);
        let bound = rule.bound(&stats);
        assert!(!bound.is_nan(), "NaN bound for {stats:?} (fired={fired})");
    }
}

#[test]
fn grid_handles_constant_features() {
    // constant column → all quantile cuts identical; stumps on it have
    // edge exactly -sum_w or +sum_w depending on side — never certified
    // as informative vs a ±1 label coin, and never a crash
    let mut block = DataBlock::empty(2);
    let mut rng = Rng::new(5);
    for _ in 0..200 {
        block.push(
            &[3.25, rng.gauss() as f32],
            if rng.bernoulli(0.5) { 1.0 } else { -1.0 },
        );
    }
    let grid = CandidateGrid::from_quantiles(&block, 4);
    assert!(grid.row(0).iter().all(|&t| t == 3.25));
    let w = vec![1.0f32; 200];
    let m = edges_native(&block, &w, &grid);
    for t in 0..4 {
        // x > 3.25 is false for all → h = -1 always → edge = -Σ u = -(Σ w y)
        let label_sum: f64 = block.labels.iter().map(|&y| y as f64).sum();
        assert!((m.edge(0, t) + label_sum).abs() < 1e-6);
    }
}

#[test]
fn empty_and_single_example_samples() {
    use sparrow::data::SampleSet;
    let empty = SampleSet::empty(4);
    assert_eq!(empty.n_eff(), 0.0);
    assert_eq!(empty.total_weight(), 0.0);

    let mut block = DataBlock::empty(1);
    block.push(&[0.5], 1.0);
    let single = SampleSet::fresh(block, vec![0.0], 0);
    assert!((single.n_eff() - 1.0).abs() < 1e-9);
}

#[test]
fn checkpoint_resume_path_is_total_under_file_corruption() {
    use sparrow::tmsn::BoostPayload;
    use sparrow::worker::write_checkpoint;

    let scratch = ScratchDir::unique("ckpt_fuzz");
    prop_check("corrupted checkpoints never panic", 50, |rng| {
        // a valid checkpoint pair, as `--checkpoint` writes it
        let mut m = StrongRule::new();
        for t in 0..gen::size(rng, 1, 8) {
            m.push(Stump::new(t as u32, rng.gauss() as f32, 1.0), 0.1);
        }
        let bound = 0.01 + rng.f64() * 0.9;
        let path = scratch.0.join(format!("w_{}.ckpt", rng.next_u64()));
        let path = path.to_str().unwrap().to_string();
        write_checkpoint(&path, &BoostPayload::resume(m.clone(), bound))
            .map_err(|e| e.to_string())?;

        // corrupt it the way a crash mid-write or disk fault would
        let corrupted = rng.bernoulli(0.7);
        if corrupted {
            match rng.below(4) {
                0 => {
                    // truncate the model text at a random byte
                    let text = std::fs::read_to_string(&path).map_err(|e| e.to_string())?;
                    let cut = rng.below(text.len().max(1) as u64) as usize;
                    std::fs::write(&path, &text[..cut]).map_err(|e| e.to_string())?;
                }
                1 => {
                    // flip a byte in the model text
                    let mut bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
                    if !bytes.is_empty() {
                        let i = rng.below(bytes.len() as u64) as usize;
                        bytes[i] = bytes[i].wrapping_add(1 + rng.below(200) as u8);
                    }
                    std::fs::write(&path, &bytes).map_err(|e| e.to_string())?;
                }
                2 => {
                    // garbage meta
                    std::fs::write(format!("{path}.meta"), "bound=not_a_number\n")
                        .map_err(|e| e.to_string())?;
                }
                _ => {
                    // missing meta (kill between the two renames)
                    std::fs::remove_file(format!("{path}.meta")).ok();
                }
            }
        }

        // the exact read-back `sparrow worker --resume <path>` performs:
        // parse the model text, then token-scan the meta for `bound=`
        let outcome = std::panic::catch_unwind(|| {
            let model = std::fs::read_to_string(&path)
                .map_err(|e| e.to_string())
                .and_then(|t| StrongRule::from_text(&t));
            let meta_bound = std::fs::read_to_string(format!("{path}.meta"))
                .ok()
                .and_then(|meta| {
                    meta.split_whitespace()
                        .find_map(|t| t.strip_prefix("bound=").map(str::to_string))
                })
                .and_then(|v| v.parse::<f64>().ok());
            (model, meta_bound)
        });
        let cleanup = || {
            std::fs::remove_file(&path).ok();
            std::fs::remove_file(format!("{path}.meta")).ok();
        };
        let (model, meta_bound) = match outcome {
            Err(_) => {
                cleanup();
                return Err("resume read path panicked".into());
            }
            Ok(pair) => pair,
        };
        // an untouched checkpoint must round-trip exactly
        if !corrupted {
            let got = model.map_err(|e| format!("clean checkpoint rejected: {e}"))?;
            if got.to_text() != m.to_text() {
                cleanup();
                return Err("clean checkpoint model drifted".into());
            }
            match meta_bound {
                Some(b) if (b - bound).abs() < 1e-12 => {}
                other => {
                    cleanup();
                    return Err(format!("clean checkpoint bound drifted: {other:?}"));
                }
            }
        }
        cleanup();
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// self-healing fabric (DESIGN.md §13): PEX/PING dialect fails closed
// ---------------------------------------------------------------------------

mod fabric_fuzz {
    use super::*;
    use std::io::Write;
    use std::net::TcpStream;
    use std::time::Duration;

    use sparrow::network::pex::{decode_pex, encode_pex, PexMsg, PexTable};
    use sparrow::network::TcpEndpoint;
    use sparrow::tmsn::BoostPayload;

    // the link wire format, rebuilt from its documented layout (magic +
    // LE length + payload; payload = tag byte + rest) — deliberately NOT
    // the crate's own frame_bytes, so these attacks cover the real bytes
    const MAGIC: u32 = 0x544D_534E;
    const TAG_PING: u8 = 0x01;
    const TAG_PEX: u8 = 0x02;

    fn frame(payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + payload.len());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(payload);
        out
    }

    fn pex_frame(ttl: u8, msg: &PexMsg) -> Vec<u8> {
        let mut payload = vec![TAG_PEX, ttl];
        payload.extend_from_slice(&encode_pex(msg));
        frame(&payload)
    }

    #[test]
    fn pex_decoder_is_total_and_rejects_every_truncation() {
        prop_check("pex decode total", 200, |rng| {
            // arbitrary bytes must never panic the decoder
            let len = gen::size(rng, 0, 300);
            let junk: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            std::panic::catch_unwind(|| {
                let _ = decode_pex(&junk);
            })
            .map_err(|_| "decode_pex panicked on junk".to_string())?;

            // a valid encoding round-trips …
            let n = gen::size(rng, 0, 8);
            let msg = PexMsg {
                version: rng.next_u64(),
                addrs: (0..n).map(|i| format!("10.0.0.{i}:{}", 1024 + i)).collect(),
            };
            let bytes = encode_pex(&msg);
            let back = decode_pex(&bytes).map_err(|e| format!("valid pex rejected: {e}"))?;
            if back != msg {
                return Err("pex roundtrip drifted".into());
            }
            // … and every strict prefix fails closed (the count in the
            // header promises more than the body delivers)
            let cut = rng.below(bytes.len() as u64) as usize;
            if decode_pex(&bytes[..cut]).is_ok() {
                return Err(format!("truncation at {cut}/{} accepted", bytes.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn self_announce_loops_die_in_the_table() {
        // the anti-loop argument: our own advertised address is never
        // fresh, so an echoed self-announce produces nothing to dial or
        // relay and the gossip loop terminates immediately
        let mut table = PexTable::new("127.0.0.1:7000");
        let v0 = table.version();
        let echo = PexMsg {
            version: 99,
            addrs: vec!["127.0.0.1:7000".into(), "127.0.0.1:7000".into()],
        };
        assert!(table.absorb(&echo).is_empty());
        assert_eq!(table.version(), v0, "self-echo bumped the version");
        // a mixed message only yields the genuinely new address
        let mixed = PexMsg {
            version: 100,
            addrs: vec!["127.0.0.1:7000".into(), "127.0.0.1:7001".into()],
        };
        assert_eq!(table.absorb(&mixed), vec!["127.0.0.1:7001".to_string()]);
        assert!(table.absorb(&mixed).is_empty(), "second absorb re-freshed");
    }

    #[test]
    fn malformed_fabric_frames_drop_the_link_not_the_endpoint() {
        let a: TcpEndpoint<BoostPayload> = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        a.enable_pex();
        let addr = a.local_addr().to_string();

        let attacks: Vec<Vec<u8>> = vec![
            b"GARBAGE-NOT-A-FRAME-AT-ALL".to_vec(),   // bad magic
            MAGIC.to_le_bytes()[..3].to_vec(),        // truncated header
            frame(&[]),                               // empty payload
            frame(&[0x7F, 1, 2, 3]),                  // unknown tag
            frame(&[TAG_PEX]),                        // PEX with no ttl/body
            frame(&[TAG_PEX, 3, 1, 2, 3]),            // PEX truncated body
            {
                // oversized length prefix
                let mut f = MAGIC.to_le_bytes().to_vec();
                f.extend_from_slice(&u32::MAX.to_le_bytes());
                f
            },
        ];
        for (i, attack) in attacks.iter().enumerate() {
            let mut s = TcpStream::connect(&addr).unwrap_or_else(|e| {
                panic!("attack {i}: endpoint stopped accepting: {e}")
            });
            let _ = s.write_all(attack);
            // the endpoint must drop this link, not its acceptor
        }
        // PING + trailing junk is tolerated by contract (liveness only,
        // never delivered as a payload)
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(&frame(&[TAG_PING, 0xDE, 0xAD])).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        assert!(a.try_recv().is_none(), "PING delivered as a payload");

        // after every attack the endpoint still speaks the protocol
        let b: TcpEndpoint<BoostPayload> = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        b.connect(&addr).unwrap();
        let payload = BoostPayload::resume(StrongRule::new(), 0.9);
        b.broadcast(&payload);
        let got = a.recv_timeout(Duration::from_secs(5));
        assert!(got.is_some(), "endpoint dead after malformed frames");
    }

    #[test]
    fn live_self_announce_never_dials_self() {
        let a: TcpEndpoint<BoostPayload> = TcpEndpoint::bind("127.0.0.1:0").unwrap();
        // advertise a fixed public name, then echo that exact name back
        a.enable_pex_as("127.0.0.1:39999");
        let addr = a.local_addr().to_string();
        let mut s = TcpStream::connect(&addr).unwrap();
        let echo = PexMsg {
            version: 1,
            addrs: vec!["127.0.0.1:39999".into()],
        };
        s.write_all(&pex_frame(4, &echo)).unwrap();
        std::thread::sleep(Duration::from_millis(300));
        assert_eq!(a.peer_count(), 0, "endpoint dialed its own advertisement");
        assert!(a.peer_table().is_empty(), "self address entered the table");
    }
}

#[test]
fn strong_rule_score_associativity_under_split() {
    // score_suffix split at any point reconstructs the full score
    prop_check("suffix split exact", 50, |rng| {
        let f = 4;
        let mut m = StrongRule::new();
        let t = gen::size(rng, 1, 12);
        for _ in 0..t {
            m.push(
                Stump::new(
                    rng.below(f as u64) as u32,
                    rng.gauss() as f32,
                    if rng.bernoulli(0.5) { 1.0 } else { -1.0 },
                ),
                0.05 + rng.f32() * 0.5,
            );
        }
        let row: Vec<f32> = (0..f).map(|_| rng.gauss() as f32).collect();
        let full = m.score(&row);
        let split = gen::size(rng, 0, t);
        let prefix: f32 = {
            let mut p = StrongRule::new();
            for i in 0..split {
                p.push(m.stumps()[i], m.alphas()[i]);
            }
            p.score(&row)
        };
        let got = prefix + m.score_suffix(&row, split);
        if (got - full).abs() > 1e-4 {
            return Err(format!("{got} != {full} at split {split}/{t}"));
        }
        Ok(())
    });
}
