//! Differential bit-exactness battery for the binned scan engine and its
//! lane-widened kernels (DESIGN.md §8, §14):
//!
//!     simd == scalar == rows == bruteforce
//!
//! The first two equalities are **bitwise** for every input — the lane
//! kernels replay the scalar loop's per-slot f64 summation tree exactly
//! (see `scanner::simd`), and chunk boundaries (not threads) fix the
//! merge order — so they are asserted with `to_bits` over randomized
//! blocks, grids, stripes, thread counts {1, 2, 7}, ragged
//! non-multiple-of-lane-width batch tails, threshold-equal values, ±∞
//! features, zero and subnormal weights. `rows` is bitwise on
//! single-chunk batches and 1e-9-relative beyond (a different but fixed
//! summation tree); `bruteforce` is the semantic anchor at 1e-6.
//!
//! Also here: the `BinSpec::bin_value` quantization-totality fuzz
//! (satellite: random f32 bit patterns incl. NaN-adjacent, duplicate
//! thresholds, `x > thr[t] ⟺ bin(x) > t` exactly) and the exhaustive
//! u8-boundary sweep (nthr = 255, all bins reachable).
//!
//! Without `--features simd` the battery still runs every scalar/rows/
//! bruteforce assertion — the lane legs compile away, and a dedicated
//! test pins that the default build's backend is the scalar kernel.

use sparrow::boosting::{edges::edges_bruteforce, CandidateGrid, EdgeMatrix};
use sparrow::data::{BinSpec, BinnedBatch, DataBlock, SampleSet};
use sparrow::model::StrongRule;
use sparrow::scanner::{
    lane_kernel, BatchResult, BinnedBackend, NativeBackend, ScanBackend, Scanner, ScannerConfig,
    BIN_CHUNK,
};
use sparrow::stopping::LilRule;
use sparrow::util::prop::{gen, prop_check};
use sparrow::util::rng::Rng;

/// The bucket-accumulation kernels available in this build: the scalar
/// loop always; the lane path when compiled in (`--features simd`).
fn kernel_modes() -> Vec<(&'static str, bool)> {
    let mut v = vec![("scalar", false)];
    if cfg!(feature = "simd") {
        v.push(("lanes", true));
    }
    v
}

fn random_block(rng: &mut Rng, n: usize, f: usize) -> DataBlock {
    DataBlock::new(n, f, gen::normal_vec(rng, n * f), gen::labels(rng, n, 0.4))
}

/// Snap some features to exact grid thresholds and set a few to ±∞ —
/// every bin boundary case the quantization must get exactly right.
fn inject_boundary_values(rng: &mut Rng, block: &mut DataBlock, grid: &CandidateGrid) {
    let n = block.n;
    let f = block.f;
    for _ in 0..(n * f / 4).max(1) {
        let i = rng.below(n as u64) as usize;
        let j = rng.below(f as u64) as usize;
        block.features[i * f + j] = match rng.below(4) {
            0 => f32::INFINITY,
            1 => f32::NEG_INFINITY,
            _ => grid.row(j)[rng.below(grid.nthr as u64) as usize],
        };
    }
}

/// Hostile reference weights: skewed positives with injected exact zeros
/// (u = 0·y = ±0.0 — the sign case the lane select must preserve) and
/// f32 subnormals (the underflow case).
fn hostile_weights(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut w = gen::skewed_weights(rng, n, 2.0);
    for x in w.iter_mut() {
        match rng.below(10) {
            0 => *x = 0.0,
            1 => *x = f32::from_bits(1 + rng.below(0x7f_ffff) as u32), // subnormal
            _ => {}
        }
    }
    w
}

/// Gather a full-batch `BinnedBatch` for `block` under `grid`/`stripe`.
fn bins_for(block: &DataBlock, grid: &CandidateGrid, stripe: (usize, usize)) -> BinnedBatch {
    let stripe_bins = grid.bin_spec(stripe).bin_block(block);
    let idx: Vec<usize> = (0..block.n).collect();
    let mut b = BinnedBatch::default();
    b.gather(&stripe_bins, &idx);
    b
}

/// Assert two EdgeMatrix accumulations are bitwise identical over the
/// stripe columns (edges) and globally (stopping scalars).
fn assert_bitwise(a: &EdgeMatrix, b: &EdgeMatrix, stripe: (usize, usize), ctx: &str) {
    for f in stripe.0..stripe.1 {
        for t in 0..a.nthr {
            assert_eq!(
                a.edge(f, t).to_bits(),
                b.edge(f, t).to_bits(),
                "{ctx}: edge f={f} t={t}: {} vs {}",
                a.edge(f, t),
                b.edge(f, t)
            );
        }
    }
    assert_eq!(a.sum_w.to_bits(), b.sum_w.to_bits(), "{ctx}: sum_w");
    assert_eq!(a.sum_w2.to_bits(), b.sum_w2.to_bits(), "{ctx}: sum_w2");
    assert_eq!(a.count, b.count, "{ctx}: count");
}

/// Run the binned engine over every (thread count × kernel mode) config
/// and assert all results are bitwise identical; returns one of them.
fn binned_all_configs(
    block: &DataBlock,
    bins: &BinnedBatch,
    w_ref: &[f32],
    grid: &CandidateGrid,
    stripe: (usize, usize),
) -> BatchResult {
    let n = block.n;
    let s_ref = vec![0.0f32; n];
    let l_ref = vec![0u32; n];
    let model = StrongRule::new(); // empty → weights == w_ref exactly
    let mut reference: Option<(String, BatchResult)> = None;
    for threads in [1usize, 2, 7] {
        for (mode, lanes) in kernel_modes() {
            let mut be = BinnedBackend::with_simd(threads, lanes);
            let mut out = BatchResult::zeros(grid.f, grid.nthr);
            be.scan_batch_into(
                block,
                Some(bins),
                w_ref,
                &s_ref,
                &l_ref,
                &model,
                grid,
                stripe,
                &mut out,
            );
            match &reference {
                None => reference = Some((format!("{mode} t={threads}"), out)),
                Some((ref_name, r)) => assert_bitwise(
                    &r.edges,
                    &out.edges,
                    stripe,
                    &format!("{ref_name} vs {mode} t={threads} (n={n})"),
                ),
            }
        }
    }
    reference.unwrap().1
}

#[test]
fn prop_simd_scalar_rows_bruteforce_differential() {
    prop_check("simd == scalar == rows == bruteforce", 40, |rng| {
        let n = gen::size(rng, 1, 1300); // spans 1–3 BIN_CHUNK chunks
        let f = gen::size(rng, 1, 9);
        let nthr = gen::size(rng, 1, 9);
        let mut block = random_block(rng, n, f);
        let grid = CandidateGrid::uniform(f, nthr, -2.0, 2.0);
        inject_boundary_values(rng, &mut block, &grid);
        let fs = rng.below(f as u64) as usize;
        let fe = fs + 1 + rng.below((f - fs) as u64) as usize;
        let w_ref = hostile_weights(rng, n);
        let s_ref = vec![0.0f32; n];
        let l_ref = vec![0u32; n];
        let model = StrongRule::new();

        let mut rows = NativeBackend;
        let a = rows.scan_batch(&block, &w_ref, &s_ref, &l_ref, &model, &grid, (fs, fe));
        let bins = bins_for(&block, &grid, (fs, fe));
        let b = binned_all_configs(&block, &bins, &w_ref, &grid, (fs, fe));

        // binned (any kernel, any thread count) vs rows
        if a.edges.sum_w.to_bits() != b.edges.sum_w.to_bits()
            || a.edges.sum_w2.to_bits() != b.edges.sum_w2.to_bits()
            || a.edges.count != b.edges.count
        {
            return Err("stopping scalars diverged rows vs binned".into());
        }
        let brute = edges_bruteforce(&block, &w_ref, &grid);
        for ff in fs..fe {
            for t in 0..nthr {
                let ea = a.edges.edge(ff, t);
                let eb = b.edges.edge(ff, t);
                let ec = brute.edge(ff, t);
                if n <= BIN_CHUNK {
                    // single chunk: identical summation tree → bitwise
                    if ea.to_bits() != eb.to_bits() {
                        return Err(format!(
                            "single-chunk bit mismatch f={ff} t={t}: {ea} vs {eb} (n={n})"
                        ));
                    }
                } else if (ea - eb).abs() > 1e-9 * (1.0 + ea.abs()) {
                    return Err(format!("binned vs rows f={ff} t={t}: {eb} vs {ea} (n={n})"));
                }
                if (ea - ec).abs() > 1e-6 * (1.0 + ec.abs()) {
                    return Err(format!("rows vs brute f={ff} t={t}: {ea} vs {ec}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn ragged_tails_bit_identical() {
    // the classic SIMD remainder bug: batch sizes around the lane width
    // (4) and the chunk width (512) — every kernel × thread config must
    // agree bitwise on every tail shape
    let mut rng = Rng::new(171);
    let (f, nthr) = (3usize, 5usize);
    let grid = CandidateGrid::uniform(f, nthr, -1.5, 1.5);
    for n in [
        1usize, 2, 3, 4, 5, 7, 8, 9, 511, 512, 513, 515, 1023, 1024, 1025, 1027,
    ] {
        let mut block = random_block(&mut rng, n, f);
        inject_boundary_values(&mut rng, &mut block, &grid);
        let w_ref = hostile_weights(&mut rng, n);
        let bins = bins_for(&block, &grid, (0, f));
        // all configs bitwise-agree (asserted inside), including tails
        let _ = binned_all_configs(&block, &bins, &w_ref, &grid, (0, f));
    }
}

#[test]
fn full_scan_path_identical_scores_weights_edges() {
    // through scan_batch_into with a non-empty model: the incremental
    // scoring/weight refresh is shared row-view code, so scores and
    // weights must be bitwise equal across kernels too
    let mut rng = Rng::new(172);
    let n = BIN_CHUNK + 77;
    let (f, nthr) = (6usize, 4usize);
    let block = random_block(&mut rng, n, f);
    let grid = CandidateGrid::uniform(f, nthr, -1.5, 1.5);
    let mut model = StrongRule::new();
    for k in 0..4u32 {
        model.push(
            sparrow::model::Stump::new(k % f as u32, 0.1 * k as f32 - 0.2, 1.0),
            0.1 + 0.05 * k as f32,
        );
    }
    let w_ref = hostile_weights(&mut rng, n);
    let s_ref = vec![0.0f32; n];
    let l_ref = vec![0u32; n];
    let bins = bins_for(&block, &grid, (0, f));
    let mut reference: Option<BatchResult> = None;
    for threads in [1usize, 2, 7] {
        for (mode, lanes) in kernel_modes() {
            let mut be = BinnedBackend::with_simd(threads, lanes);
            let mut out = BatchResult::zeros(f, nthr);
            be.scan_batch_into(
                &block,
                Some(&bins),
                &w_ref,
                &s_ref,
                &l_ref,
                &model,
                &grid,
                (0, f),
                &mut out,
            );
            match &reference {
                None => reference = Some(out),
                Some(r) => {
                    assert_eq!(r.scores, out.scores, "{mode} t={threads}: scores");
                    assert_eq!(r.weights, out.weights, "{mode} t={threads}: weights");
                    assert_bitwise(&r.edges, &out.edges, (0, f), &format!("{mode} t={threads}"));
                }
            }
        }
    }
}

#[test]
fn scanner_outcome_identical_across_kernels() {
    // end to end through Scanner::run_pass: the kernel knob must not
    // change a single certified answer, refreshed weight, or cursor
    for (mode, lanes) in kernel_modes() {
        let mut rng = Rng::new(173);
        let (n, f) = (2000usize, 4usize);
        let mut block = DataBlock::empty(f);
        for _ in 0..n {
            let y = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            let mut row: Vec<f32> = (0..f).map(|_| rng.gauss() as f32).collect();
            row[0] = y * (1.0 + rng.f32());
            block.push(&row, y);
        }
        let mk_scanner = |simd: bool| {
            Scanner::new(
                CandidateGrid::uniform(f, 3, -1.0, 1.0),
                (0, f),
                Box::new(BinnedBackend::with_simd(2, simd)),
                Box::new(LilRule::default()),
                ScannerConfig {
                    batch: 64,
                    ..ScannerConfig::default()
                },
            )
        };
        let mut sample_scalar = SampleSet::fresh(block.clone(), vec![0.0; n], 0);
        let mut sample_lanes = sample_scalar.clone();
        let model = StrongRule::new();
        let a = mk_scanner(false).run_pass(&mut sample_scalar, &model, || false);
        let b = mk_scanner(lanes).run_pass(&mut sample_lanes, &model, || false);
        assert_eq!(a, b, "outcome diverged ({mode})");
        assert_eq!(sample_scalar.w_last, sample_lanes.w_last, "weights ({mode})");
    }
}

#[test]
fn default_backend_is_scalar_kernel() {
    // the acceptance off-path: `BinnedBackend::new` (what `--scan-simd
    // auto` resolves to without the feature, and `off` always) runs the
    // scalar kernel, and without `--features simd` no lane kernel exists
    assert_eq!(BinnedBackend::new(4).kernel(), "scalar");
    if cfg!(feature = "simd") {
        assert!(["avx2", "portable"].contains(&lane_kernel()));
    } else {
        assert_eq!(lane_kernel(), "compiled-out");
    }
}

#[cfg(feature = "simd")]
#[test]
fn portable_and_dispatch_kernels_match_scalar_scatter() {
    // kernel-level pin: the portable lane kernel AND whatever kernel the
    // runtime ladder dispatches to both replay the scalar scatter bit
    // for bit — on every CPU, not just whichever the ladder picks
    use sparrow::scanner::simd::{accumulate_column, accumulate_column_portable};
    let mut rng = Rng::new(174);
    for nslots in [1usize, 4, 5, 6, 9, 13, 17, 256] {
        for n in [1usize, 3, 5, 513] {
            let bins: Vec<u8> = (0..n).map(|_| rng.below(nslots as u64) as u8).collect();
            let u: Vec<f64> = (0..n)
                .map(|_| match rng.below(8) {
                    0 => 0.0,
                    1 => -0.0,
                    2 => f64::from_bits(1 + rng.below(1000)), // subnormal
                    _ => rng.gauss() * 1e3,
                })
                .collect();
            let mut want = vec![0.0f64; nslots];
            for i in 0..n {
                want[bins[i] as usize] += u[i];
            }
            let mut portable = vec![0.0f64; nslots];
            accumulate_column_portable(&bins, &u, 0, n, &mut portable);
            let mut dispatched = vec![0.0f64; nslots];
            accumulate_column(&bins, &u, 0, n, &mut dispatched);
            for s in 0..nslots {
                assert_eq!(want[s].to_bits(), portable[s].to_bits(), "portable slot {s}");
                assert_eq!(want[s].to_bits(), dispatched[s].to_bits(), "dispatch slot {s}");
            }
        }
    }
}

// ---- BinSpec::bin quantization totality (satellite) -----------------------

/// Reference predicate count: thresholds strictly below `x` (the row
/// engine's loop, re-stated independently).
fn strict_exceedances(x: f32, thr: &[f32]) -> usize {
    thr.iter().filter(|&&t| x > t).count()
}

#[test]
fn prop_bin_value_totality_fuzz() {
    // seeded fuzz: for ANY f32 bit pattern x — normals, subnormals, ±0,
    // ±∞, NaNs with random payloads ("NaN-adjacent" exponent-0xFF
    // patterns included) — and ascending rows WITH duplicates,
    // x > thr[t] ⟺ bin(x) > t must hold exactly for every t
    prop_check("bin(x) counts strict exceedances totally", 60, |rng| {
        let nthr = gen::size(rng, 1, 12);
        // few distinct values, repeated → duplicate thresholds, sorted
        let mut thr: Vec<f32> = Vec::with_capacity(nthr);
        let distinct = 1 + rng.below(4u64.min(nthr as u64));
        let pool: Vec<f32> = (0..distinct).map(|_| rng.gauss() as f32).collect();
        for _ in 0..nthr {
            thr.push(pool[rng.below(distinct) as usize]);
        }
        thr.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let spec = BinSpec::new((0, 1), nthr, thr.clone());
        for _ in 0..200 {
            // raw bit patterns: ~1/256 are ±∞, ~0.4% NaN, plus targeted
            // NaN-adjacent patterns around 0x7f80_0000 / 0xff80_0000
            let jitter = |rng: &mut Rng| (rng.below(9) as u32).wrapping_sub(4);
            let x = match rng.below(8) {
                0 => f32::from_bits(0x7f80_0000u32.wrapping_add(jitter(rng))),
                1 => f32::from_bits(0xff80_0000u32.wrapping_add(jitter(rng))),
                2 => thr[rng.below(nthr as u64) as usize], // exact threshold hit
                _ => f32::from_bits(rng.next_u64() as u32),
            };
            let bin = spec.bin_value(0, x) as usize;
            let want = strict_exceedances(x, &thr);
            if bin != want {
                return Err(format!("bin({x:?}) = {bin}, want {want} (thr={thr:?})"));
            }
            for t in 0..nthr {
                if (x > thr[t]) != (bin > t) {
                    return Err(format!(
                        "equivalence broken at t={t}: x={x:?} thr={} bin={bin}",
                        thr[t]
                    ));
                }
            }
            if x.is_nan() && bin != 0 {
                return Err(format!("NaN must bin to 0, got {bin}"));
            }
        }
        Ok(())
    });
}

#[test]
fn bin_value_u8_boundary_exhaustive() {
    // the full u8 range: nthr = 255 distinct ascending thresholds; every
    // threshold is hit exactly (strict exceedance ⇒ bin(thr[t]) == t),
    // every one of the 256 bin values is reachable, and the f32 next-up
    // of each threshold lands one bin higher
    let nthr = 255usize;
    let thr: Vec<f32> = (0..nthr).map(|t| t as f32).collect();
    let spec = BinSpec::new((0, 1), nthr, thr.clone());
    let mut seen = [false; 256];
    for t in 0..nthr {
        let at = spec.bin_value(0, thr[t]) as usize;
        assert_eq!(at, t, "bin(thr[{t}]) must equal {t} (strict exceedance)");
        seen[at] = true;
        let up = f32::from_bits(thr[t].to_bits() + 1); // next representable
        assert_eq!(spec.bin_value(0, up) as usize, t + 1, "next-up of thr[{t}]");
        for probe_t in 0..nthr {
            assert_eq!(
                thr[t] > thr[probe_t],
                at > probe_t,
                "equivalence at boundary t={t}, probe={probe_t}"
            );
        }
    }
    assert_eq!(spec.bin_value(0, 1e9), 255, "above all thresholds");
    seen[255] = true;
    assert_eq!(spec.bin_value(0, -1.0), 0);
    assert_eq!(spec.bin_value(0, f32::NEG_INFINITY), 0);
    assert_eq!(spec.bin_value(0, f32::INFINITY), 255);
    assert!(seen.iter().all(|&s| s), "every u8 bin value reachable");
    // all-duplicate row: the only reachable bins are 0 and nthr
    let dup = BinSpec::new((0, 1), nthr, vec![1.5f32; nthr]);
    assert_eq!(dup.bin_value(0, 1.5), 0);
    assert_eq!(dup.bin_value(0, 1.0), 0);
    assert_eq!(dup.bin_value(0, 2.0), 255);
}
