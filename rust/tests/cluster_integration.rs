//! Cluster-level integration tests: TMSN protocol invariants observed on
//! real multi-threaded runs (event-log causality, bound monotonicity,
//! robustness to message loss and laggards).

mod common;

use std::time::Duration;

use sparrow::config::TrainConfig;
use sparrow::coordinator::{train_cluster, ClusterOutcome};
use sparrow::metrics::EventKind;
use sparrow::network::NetConfig;
use sparrow::scanner::NativeBackend;

fn run(patch: impl FnOnce(&mut TrainConfig)) -> ClusterOutcome {
    let (path, test) = common::synth_store("sparrow_cluster_int", 99, 20_000, 2_000);
    let mut cfg = TrainConfig {
        num_workers: 4,
        sample_size: 2048,
        max_rules: 16,
        time_limit: Duration::from_secs(30),
        gamma0: 0.2,
        ..TrainConfig::default()
    };
    patch(&mut cfg);
    train_cluster(&cfg, &path, &test, "int", &|_| Ok(Box::new(NativeBackend))).unwrap()
}

#[test]
fn every_accept_has_a_matching_broadcast() {
    let out = run(|_| {});
    let broadcasts: Vec<(usize, u64)> = out
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Broadcast || e.kind == EventKind::LocalImprovement)
        .filter_map(|e| e.model)
        .collect();
    let accepts: Vec<&sparrow::metrics::Event> = out
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Accept)
        .collect();
    assert!(!accepts.is_empty(), "no accepts in a 4-worker run");
    for a in accepts {
        let origin = a.model.expect("accept without model version");
        assert!(
            broadcasts.contains(&origin),
            "accepted model {origin:?} never broadcast"
        );
    }
}

#[test]
fn per_worker_bounds_monotone_in_event_log() {
    let out = run(|_| {});
    let mut bound = vec![f64::INFINITY; 4];
    for e in &out.events {
        if matches!(e.kind, EventKind::LocalImprovement | EventKind::Accept) {
            assert!(
                e.value <= bound[e.worker] + 1e-9,
                "worker {} bound went up: {} -> {}",
                e.worker,
                bound[e.worker],
                e.value
            );
            bound[e.worker] = e.value;
        }
    }
    // final reported bound equals the min over workers
    let min_bound = out
        .workers
        .iter()
        .map(|w| w.loss_bound)
        .fold(f64::INFINITY, f64::min);
    assert!((out.loss_bound - min_bound).abs() < 1e-9);
}

#[test]
fn tolerates_heavy_message_loss() {
    let out = run(|c| {
        c.net = NetConfig {
            drop_rate: 0.7,
            ..NetConfig::default()
        };
    });
    // progress despite 70% loss: every worker learns locally even if
    // gossip rarely lands
    assert!(!out.model.is_empty());
    let (_, _, dropped) = out.net;
    assert!(dropped > 0, "drop injection had no effect");
}

#[test]
fn laggard_worker_does_not_block_others() {
    let out = run(|c| {
        c.laggards = vec![(0, 20.0)];
        c.max_rules = 12;
    });
    assert!(out.model.len() >= 12, "cluster blocked by laggard");
    // the healthy workers did the finding
    let healthy_found: u64 = out.workers.iter().skip(1).map(|w| w.found).sum();
    let laggard_found = out.workers[0].found;
    assert!(
        healthy_found > laggard_found,
        "healthy {healthy_found} vs laggard {laggard_found}"
    );
}

#[test]
fn resample_events_bracketed() {
    let out = run(|_| {});
    // every worker: ResampleStart/End alternate properly
    for w in 0..4 {
        let mut depth = 0i32;
        for e in out.events.iter().filter(|e| e.worker == w) {
            match e.kind {
                EventKind::ResampleStart => {
                    depth += 1;
                    assert_eq!(depth, 1, "nested resample on worker {w}");
                }
                EventKind::ResampleEnd => {
                    depth -= 1;
                    assert_eq!(depth, 0, "unmatched ResampleEnd on worker {w}");
                }
                _ => {}
            }
        }
    }
}

#[test]
fn final_model_loss_bound_is_sound_on_train_sample() {
    // certified bound >= actual training-set potential, w.h.p. — checked
    // against the full training set (bound soundness, §2)
    let (path, _) = common::synth_store("sparrow_cluster_int", 99, 20_000, 2_000);
    let out = run(|c| c.max_rules = 10);
    let train = sparrow::data::DiskStore::open(&path)
        .unwrap()
        .read_all()
        .unwrap();
    let actual = sparrow::eval::exp_loss(&out.model, &train);
    // allow slack for f32 + sampling noise: the bound certifies the
    // potential up to the stopping rule's failure probability
    assert!(
        actual <= out.loss_bound * 1.25 + 0.05,
        "bound {} badly violated by actual {}",
        out.loss_bound,
        actual
    );
}

// ---------------------------------------------------------------------------
// elastic swarm on the real TCP path: kill a worker, restart with --resume
// ---------------------------------------------------------------------------

mod tcp_resume {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::{Arc, Mutex};
    use std::thread;
    use std::time::Instant;

    use sparrow::admin::ControlState;
    use sparrow::boosting::grid::partition_features;
    use sparrow::boosting::CandidateGrid;
    use sparrow::data::{DiskStore, IoThrottle};
    use sparrow::metrics::EventLog;
    use sparrow::model::StrongRule;
    use sparrow::network::TcpEndpoint;
    use sparrow::serve::ModelSlot;
    use sparrow::tmsn::{BoostPayload, Link};
    use sparrow::worker::{run_worker, ControlPlane, WorkerParams};

    /// A shareable TCP link: the worker thread uses it as its transport
    /// while the test keeps a handle — needed to redial the restarted
    /// worker's fresh listener, exactly what a long-lived `sparrow worker`
    /// process does when a rebooted peer comes back at a new address.
    struct SharedTcp(Arc<Mutex<TcpEndpoint<BoostPayload>>>);

    impl Link<BoostPayload> for SharedTcp {
        fn send(&self, msg: BoostPayload) {
            self.0.lock().unwrap().broadcast(&msg);
        }
        fn poll(&self) -> Option<BoostPayload> {
            self.0.lock().unwrap().try_recv()
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn worker_params(
        id: usize,
        store_path: &std::path::Path,
        endpoint: Box<dyn Link<BoostPayload>>,
        stop: Arc<AtomicBool>,
        state: Arc<ControlState>,
        slot: Arc<ModelSlot>,
        patch: impl FnOnce(&mut TrainConfig),
    ) -> WorkerParams {
        let store = DiskStore::open(store_path).unwrap();
        let features = store.num_features();
        let pilot = store
            .stream(IoThrottle::unlimited())
            .unwrap()
            .next_block(2048)
            .unwrap();
        let grid = CandidateGrid::from_quantiles(&pilot, 4);
        let stripe = partition_features(features, 2)[id];
        let mut cfg = TrainConfig {
            num_workers: 2,
            sample_size: 512,
            max_rules: 10_000,
            time_limit: Duration::from_secs(30),
            gamma0: 0.2,
            ..TrainConfig::default()
        };
        patch(&mut cfg);
        let (log, _rx) = EventLog::new();
        let log = log.with_counters(Arc::clone(&state.counters));
        WorkerParams {
            id,
            cfg,
            grid,
            stripe,
            store,
            endpoint,
            log,
            stop,
            backend: Box::new(NativeBackend),
            laggard: 1.0,
            crash_after: None,
            seed: 17 + id as u64,
            control: Some(ControlPlane {
                state,
                slot,
            }),
        }
    }

    #[test]
    fn killed_tcp_worker_resumes_from_checkpoint_and_catches_up() {
        let (store_path, _test) = common::synth_store("sparrow_tcp_resume", 7, 8_000, 200);
        let scratch =
            std::env::temp_dir().join(format!("sparrow_tcp_resume_{}", std::process::id()));
        std::fs::create_dir_all(&scratch).unwrap();
        let ckpt = scratch.join("worker0.ckpt").to_str().unwrap().to_string();

        // the long-lived peer (worker 1), on a shareable TCP endpoint
        let ep1 = Arc::new(Mutex::new(
            TcpEndpoint::<BoostPayload>::bind("127.0.0.1:0").unwrap(),
        ));
        let addr1 = ep1.lock().unwrap().local_addr().to_string();
        let stop1 = Arc::new(AtomicBool::new(false));
        let state1 = Arc::new(ControlState::new());
        let slot1 = Arc::new(ModelSlot::new());
        let h1 = {
            let p = worker_params(
                1,
                &store_path,
                Box::new(SharedTcp(Arc::clone(&ep1))),
                Arc::clone(&stop1),
                Arc::clone(&state1),
                slot1,
                |_| {},
            );
            thread::spawn(move || run_worker(p))
        };

        // phase 1: worker 0 trains with --checkpoint over real TCP …
        let ep0 = TcpEndpoint::<BoostPayload>::bind("127.0.0.1:0").unwrap();
        ep0.connect(&addr1).unwrap();
        ep1.lock()
            .unwrap()
            .connect(&ep0.local_addr().to_string())
            .unwrap();
        let stop0 = Arc::new(AtomicBool::new(false));
        let state0 = Arc::new(ControlState::new());
        let slot0 = Arc::new(ModelSlot::new());
        let ckpt_cfg = ckpt.clone();
        let h0 = {
            let p = worker_params(
                0,
                &store_path,
                Box::new(ep0),
                Arc::clone(&stop0),
                Arc::clone(&state0),
                slot0,
                move |c| c.checkpoint = Some(ckpt_cfg),
            );
            thread::spawn(move || run_worker(p))
        };

        // … until it has certified progress AND persisted it, then kill it
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            let (version, _, _) = state0.model();
            if version >= 2 && std::path::Path::new(&format!("{ckpt}.meta")).exists() {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "worker 0 never reached a persisted version"
            );
            thread::sleep(Duration::from_millis(5));
        }
        state0.request_crash();
        let r0 = h0.join().unwrap();
        assert!(r0.crashed, "the kill must register as a crash");

        // read back exactly the files `sparrow worker --resume <path>` reads
        let model = StrongRule::from_text(&std::fs::read_to_string(&ckpt).unwrap()).unwrap();
        let meta = std::fs::read_to_string(format!("{ckpt}.meta")).unwrap();
        let bound: f64 = meta
            .trim()
            .strip_prefix("bound=")
            .expect("meta format")
            .parse()
            .unwrap();
        assert!(!model.is_empty() && bound < 1.0, "checkpoint is not empty");

        // phase 2: restart with --resume on a fresh listener; the peer
        // redials the rebooted worker, which catches up from broadcasts
        let ep0b = TcpEndpoint::<BoostPayload>::bind("127.0.0.1:0").unwrap();
        ep0b.connect(&addr1).unwrap();
        ep1.lock()
            .unwrap()
            .connect(&ep0b.local_addr().to_string())
            .unwrap();
        let stop0b = Arc::new(AtomicBool::new(false));
        let state0b = Arc::new(ControlState::new());
        let slot0b = Arc::new(ModelSlot::new());
        // `sparrow serve --resume` seeds the slot so the checkpoint model
        // is served (at version 0) before the first live adoption
        slot0b.seed(model.clone(), bound);
        let h0b = {
            let resume = Some((model.clone(), bound));
            let ckpt_cfg = ckpt.clone();
            let p = worker_params(
                0,
                &store_path,
                Box::new(ep0b),
                Arc::clone(&stop0b),
                Arc::clone(&state0b),
                Arc::clone(&slot0b),
                move |c| {
                    c.resume = resume;
                    c.checkpoint = Some(ckpt_cfg);
                },
            );
            thread::spawn(move || run_worker(p))
        };

        // catch-up criterion: the resumed worker ACCEPTS a strictly-better
        // peer model; meanwhile the served version must never regress
        let deadline = Instant::now() + Duration::from_secs(20);
        let mut last_v = 0u64;
        loop {
            let v = slot0b.version();
            assert!(v >= last_v, "served version went backwards: {last_v} -> {v}");
            last_v = v;
            if state0b.counters.get(EventKind::Accept) >= 1 && v >= 1 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "resumed worker never caught up from broadcasts"
            );
            thread::sleep(Duration::from_millis(5));
        }

        stop0b.store(true, std::sync::atomic::Ordering::Relaxed);
        stop1.store(true, std::sync::atomic::Ordering::Relaxed);
        let r0b = h0b.join().unwrap();
        let r1 = h1.join().unwrap();
        assert!(!r0b.crashed);
        assert!(r0b.accepts >= 1, "no adoption on the resumed incarnation");
        assert!(
            r0b.loss_bound <= bound + 1e-9,
            "resume lost certified progress: {bound} -> {}",
            r0b.loss_bound
        );
        // the rejoin went through the metrics pipeline exactly once
        assert_eq!(state0b.counters.get(EventKind::Rejoin), 1);
        assert!(r1.found + r0b.found > 0);
        std::fs::remove_dir_all(&scratch).ok();
    }
}

// ---------------------------------------------------------------------------
// self-healing fabric (DESIGN.md §13): seed-node discovery, full churn
// through chaos proxies, reconnect + reconvergence — no static peer list
// ---------------------------------------------------------------------------

mod chaos_churn {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;
    use std::thread;
    use std::time::Instant;

    use sparrow::admin::ControlState;
    use sparrow::boosting::grid::partition_features;
    use sparrow::boosting::CandidateGrid;
    use sparrow::data::{DiskStore, IoThrottle};
    use sparrow::metrics::EventLog;
    use sparrow::model::StrongRule;
    use sparrow::network::{ChaosProxy, ChaosRules, TcpEndpoint, TcpTuning};
    use sparrow::serve::ModelSlot;
    use sparrow::tmsn::BoostPayload;
    use sparrow::worker::{run_worker, ControlPlane, WorkerParams, WorkerResult};

    const N: usize = 4;

    /// The seed CI sweeps via the `SPARROW_CHAOS_SEED` matrix (job
    /// `chaos`; locally `SPARROW_CHAOS_SEED=7 make chaos`).
    fn env_seed() -> u64 {
        std::env::var("SPARROW_CHAOS_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1)
    }

    /// Dumps the chaos fabric's pcap-style frame trace to
    /// `target/chaos_failures/` when the owning test panics — the
    /// artifact the chaos CI job uploads on failure.
    struct TraceGuard {
        rules: Arc<ChaosRules>,
        tag: String,
    }

    impl Drop for TraceGuard {
        fn drop(&mut self) {
            if !thread::panicking() {
                return;
            }
            let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("target/chaos_failures");
            let _ = std::fs::create_dir_all(&dir);
            let path = dir.join(format!("{}.trace.jsonl", self.tag));
            let _ = std::fs::write(&path, self.rules.trace_jsonl());
            eprintln!("chaos frame trace dumped to {}", path.display());
        }
    }

    struct Incarnation {
        handle: thread::JoinHandle<WorkerResult>,
        stop: Arc<AtomicBool>,
        state: Arc<ControlState>,
    }

    fn wait(deadline: Instant, what: &str, mut cond: impl FnMut() -> bool) {
        while !cond() {
            assert!(Instant::now() < deadline, "watchdog expired: {what}");
            thread::sleep(Duration::from_millis(10));
        }
    }

    fn up_peers(inc: &Incarnation) -> usize {
        inc.state.peers().iter().filter(|p| p.up).count()
    }

    /// Start one worker incarnation: bind is done by the caller (so the
    /// chaos proxy can be retargeted first), PEX announces the *proxy*
    /// address, and only `dial` (one seed) is contacted — discovery does
    /// the rest.
    fn launch(
        id: usize,
        store_path: &std::path::Path,
        endpoint: TcpEndpoint<BoostPayload>,
        advertised: &str,
        dial: &[String],
        resume: Option<(StrongRule, f64)>,
    ) -> Incarnation {
        // tight liveness so kill→down→redial cycles fit the watchdog
        endpoint.tune(TcpTuning {
            heartbeat: Duration::from_millis(100),
            read_timeout: Duration::from_secs(1),
            write_timeout: Duration::from_secs(1),
            queue_cap: 1024,
            backoff_base: Duration::from_millis(20),
            backoff_cap: Duration::from_millis(200),
        });
        endpoint.enable_pex_as(advertised);
        for d in dial {
            endpoint.connect(d).unwrap();
        }
        let stop = Arc::new(AtomicBool::new(false));
        let state = Arc::new(ControlState::new());
        state.set_peer_source(endpoint.peer_table_handle());
        let slot = Arc::new(ModelSlot::new());
        let (log, _rx) = EventLog::new();
        let log = log.with_counters(Arc::clone(&state.counters));
        endpoint.event_log(log.clone(), id);

        let store = DiskStore::open(store_path).unwrap();
        let features = store.num_features();
        let pilot = store
            .stream(IoThrottle::unlimited())
            .unwrap()
            .next_block(2048)
            .unwrap();
        let grid = CandidateGrid::from_quantiles(&pilot, 4);
        let stripe = partition_features(features, N)[id];
        let cfg = TrainConfig {
            num_workers: N,
            sample_size: 512,
            max_rules: 10_000,
            time_limit: Duration::from_secs(120),
            gamma0: 0.2,
            resume,
            ..TrainConfig::default()
        };
        let params = WorkerParams {
            id,
            cfg,
            grid,
            stripe,
            store,
            endpoint: Box::new(endpoint),
            log,
            stop: Arc::clone(&stop),
            backend: Box::new(NativeBackend),
            laggard: 1.0,
            crash_after: None,
            seed: 41 + id as u64,
            control: Some(ControlPlane {
                state: Arc::clone(&state),
                slot,
            }),
        };
        let handle = thread::spawn(move || run_worker(params));
        Incarnation {
            handle,
            stop,
            state,
        }
    }

    #[test]
    fn seed_discovery_survives_full_churn_through_chaos_proxies() {
        let deadline = Instant::now() + Duration::from_secs(60);
        let (store_path, _test) = common::synth_store("sparrow_chaos_churn", 11, 8_000, 200);
        let seed = env_seed();
        let rules = ChaosRules::new(seed);
        let _trace = TraceGuard {
            rules: Arc::clone(&rules),
            tag: format!("churn_seed{seed}"),
        };

        // every worker sits behind its own chaos proxy: peers only ever
        // see the proxy address, which survives the worker's restart
        let mut eps = Vec::new();
        let mut proxies = Vec::new();
        for i in 0..N {
            let ep = TcpEndpoint::<BoostPayload>::bind("127.0.0.1:0").unwrap();
            let proxy =
                ChaosProxy::spawn(&ep.local_addr().to_string(), &rules, &format!("->w{i}"))
                    .unwrap();
            proxies.push(proxy);
            eps.push(ep);
        }
        let adv: Vec<String> = proxies.iter().map(|p| p.listen_addr().to_string()).collect();

        // worker 0 is the seed; 1..N join with ONLY the seed's address
        let mut workers: Vec<Option<Incarnation>> = Vec::new();
        for (i, ep) in eps.into_iter().enumerate() {
            let dial: Vec<String> = if i == 0 { vec![] } else { vec![adv[0].clone()] };
            workers.push(Some(launch(i, &store_path, ep, &adv[i], &dial, None)));
        }

        // peer exchange must build the full mesh from one seed address
        wait(deadline, "PEX never built the full mesh", || {
            workers
                .iter()
                .all(|w| up_peers(w.as_ref().unwrap()) == N - 1)
        });

        // kill and restart every worker once, one at a time
        for i in 0..N {
            let recon_before: Vec<u64> = (0..N)
                .filter(|j| *j != i)
                .map(|j| {
                    workers[j]
                        .as_ref()
                        .unwrap()
                        .state
                        .counters
                        .get(EventKind::Reconnect)
                })
                .collect();

            let old = workers[i].take().unwrap();
            old.state.request_crash();
            let r = old.handle.join().unwrap();
            assert!(r.crashed, "worker {i}: kill must register as a crash");
            let resume = if r.model.is_empty() {
                None
            } else {
                Some((r.model.clone(), r.loss_bound))
            };

            // rebind on a fresh port, retarget the proxy (public address
            // unchanged), and rejoin via one live peer — survivors' redial
            // schedules find the proxy again on their own
            let ep = TcpEndpoint::<BoostPayload>::bind("127.0.0.1:0").unwrap();
            proxies[i].set_upstream(&ep.local_addr().to_string());
            let dial = vec![adv[(i + 1) % N].clone()];
            workers[i] = Some(launch(i, &store_path, ep, &adv[i], &dial, resume));

            // every survivor reconnects to the restarted worker …
            for (slot, j) in (0..N).filter(|j| *j != i).enumerate() {
                wait(
                    deadline,
                    &format!("survivor {j} never reconnected to restarted worker {i}"),
                    || {
                        workers[j]
                            .as_ref()
                            .unwrap()
                            .state
                            .counters
                            .get(EventKind::Reconnect)
                            > recon_before[slot]
                    },
                );
            }
            // … and the restarted worker rebuilds its full outbound mesh
            // (reconnect announces re-teach it the swarm) and makes
            // certified progress again (adoption or local find)
            wait(
                deadline,
                &format!("restarted worker {i} never rebuilt its mesh"),
                || up_peers(workers[i].as_ref().unwrap()) == N - 1,
            );
            wait(
                deadline,
                &format!("restarted worker {i} never made progress"),
                || workers[i].as_ref().unwrap().state.model().0 >= 1,
            );
        }

        // reconvergence: stop everyone; every final incarnation holds a
        // certified model, and nobody regressed past the global best
        let mut results = Vec::new();
        for w in &workers {
            w.as_ref().unwrap().stop.store(true, std::sync::atomic::Ordering::Relaxed);
        }
        for w in workers.iter_mut() {
            let inc = w.take().unwrap();
            results.push(inc.handle.join().unwrap());
        }
        for r in &results {
            assert!(!r.crashed, "worker {} crashed after its restart", r.id);
            assert!(
                !r.model.is_empty() && r.loss_bound < 1.0,
                "worker {} reconverged to nothing (bound {})",
                r.id,
                r.loss_bound
            );
        }
    }
}

#[test]
fn resume_continues_from_checkpoint() {
    // phase 1: learn a few rules
    let first = run(|c| c.max_rules = 6);
    assert!(first.model.len() >= 6);
    let ckpt_model = first.model.clone();
    let ckpt_bound = first.loss_bound;

    // phase 2: resume and extend
    let second = run(|c| {
        c.max_rules = 12;
        c.resume = Some((ckpt_model.clone(), ckpt_bound));
    });
    assert!(
        second.model.len() > ckpt_model.len(),
        "resume did not extend: {} -> {}",
        ckpt_model.len(),
        second.model.len()
    );
    assert!(
        second.loss_bound <= ckpt_bound + 1e-9,
        "resume lost bound progress: {ckpt_bound} -> {}",
        second.loss_bound
    );
}
