//! Cluster-level integration tests: TMSN protocol invariants observed on
//! real multi-threaded runs (event-log causality, bound monotonicity,
//! robustness to message loss and laggards).

mod common;

use std::time::Duration;

use sparrow::config::TrainConfig;
use sparrow::coordinator::{train_cluster, ClusterOutcome};
use sparrow::metrics::EventKind;
use sparrow::network::NetConfig;
use sparrow::scanner::NativeBackend;

fn run(patch: impl FnOnce(&mut TrainConfig)) -> ClusterOutcome {
    let (path, test) = common::synth_store("sparrow_cluster_int", 99, 20_000, 2_000);
    let mut cfg = TrainConfig {
        num_workers: 4,
        sample_size: 2048,
        max_rules: 16,
        time_limit: Duration::from_secs(30),
        gamma0: 0.2,
        ..TrainConfig::default()
    };
    patch(&mut cfg);
    train_cluster(&cfg, &path, &test, "int", &|_| Ok(Box::new(NativeBackend))).unwrap()
}

#[test]
fn every_accept_has_a_matching_broadcast() {
    let out = run(|_| {});
    let broadcasts: Vec<(usize, u64)> = out
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Broadcast || e.kind == EventKind::LocalImprovement)
        .filter_map(|e| e.model)
        .collect();
    let accepts: Vec<&sparrow::metrics::Event> = out
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Accept)
        .collect();
    assert!(!accepts.is_empty(), "no accepts in a 4-worker run");
    for a in accepts {
        let origin = a.model.expect("accept without model version");
        assert!(
            broadcasts.contains(&origin),
            "accepted model {origin:?} never broadcast"
        );
    }
}

#[test]
fn per_worker_bounds_monotone_in_event_log() {
    let out = run(|_| {});
    let mut bound = vec![f64::INFINITY; 4];
    for e in &out.events {
        if matches!(e.kind, EventKind::LocalImprovement | EventKind::Accept) {
            assert!(
                e.value <= bound[e.worker] + 1e-9,
                "worker {} bound went up: {} -> {}",
                e.worker,
                bound[e.worker],
                e.value
            );
            bound[e.worker] = e.value;
        }
    }
    // final reported bound equals the min over workers
    let min_bound = out
        .workers
        .iter()
        .map(|w| w.loss_bound)
        .fold(f64::INFINITY, f64::min);
    assert!((out.loss_bound - min_bound).abs() < 1e-9);
}

#[test]
fn tolerates_heavy_message_loss() {
    let out = run(|c| {
        c.net = NetConfig {
            drop_rate: 0.7,
            ..NetConfig::default()
        };
    });
    // progress despite 70% loss: every worker learns locally even if
    // gossip rarely lands
    assert!(!out.model.is_empty());
    let (_, _, dropped) = out.net;
    assert!(dropped > 0, "drop injection had no effect");
}

#[test]
fn laggard_worker_does_not_block_others() {
    let out = run(|c| {
        c.laggards = vec![(0, 20.0)];
        c.max_rules = 12;
    });
    assert!(out.model.len() >= 12, "cluster blocked by laggard");
    // the healthy workers did the finding
    let healthy_found: u64 = out.workers.iter().skip(1).map(|w| w.found).sum();
    let laggard_found = out.workers[0].found;
    assert!(
        healthy_found > laggard_found,
        "healthy {healthy_found} vs laggard {laggard_found}"
    );
}

#[test]
fn resample_events_bracketed() {
    let out = run(|_| {});
    // every worker: ResampleStart/End alternate properly
    for w in 0..4 {
        let mut depth = 0i32;
        for e in out.events.iter().filter(|e| e.worker == w) {
            match e.kind {
                EventKind::ResampleStart => {
                    depth += 1;
                    assert_eq!(depth, 1, "nested resample on worker {w}");
                }
                EventKind::ResampleEnd => {
                    depth -= 1;
                    assert_eq!(depth, 0, "unmatched ResampleEnd on worker {w}");
                }
                _ => {}
            }
        }
    }
}

#[test]
fn final_model_loss_bound_is_sound_on_train_sample() {
    // certified bound >= actual training-set potential, w.h.p. — checked
    // against the full training set (bound soundness, §2)
    let (path, _) = common::synth_store("sparrow_cluster_int", 99, 20_000, 2_000);
    let out = run(|c| c.max_rules = 10);
    let train = sparrow::data::DiskStore::open(&path)
        .unwrap()
        .read_all()
        .unwrap();
    let actual = sparrow::eval::exp_loss(&out.model, &train);
    // allow slack for f32 + sampling noise: the bound certifies the
    // potential up to the stopping rule's failure probability
    assert!(
        actual <= out.loss_bound * 1.25 + 0.05,
        "bound {} badly violated by actual {}",
        out.loss_bound,
        actual
    );
}

#[test]
fn resume_continues_from_checkpoint() {
    // phase 1: learn a few rules
    let first = run(|c| c.max_rules = 6);
    assert!(first.model.len() >= 6);
    let ckpt_model = first.model.clone();
    let ckpt_bound = first.loss_bound;

    // phase 2: resume and extend
    let second = run(|c| {
        c.max_rules = 12;
        c.resume = Some((ckpt_model.clone(), ckpt_bound));
    });
    assert!(
        second.model.len() > ckpt_model.len(),
        "resume did not extend: {} -> {}",
        ckpt_model.len(),
        second.model.len()
    );
    assert!(
        second.loss_bound <= ckpt_bound + 1e-9,
        "resume lost bound progress: {ckpt_bound} -> {}",
        second.loss_bound
    );
}
