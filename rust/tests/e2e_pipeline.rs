//! End-to-end pipeline through the CLI binary: gen-data → train →
//! baseline → eval, exercising argument parsing, file formats, model
//! serialization and the full training stack as a user would.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_sparrow"))
}

fn workdir() -> PathBuf {
    let dir = std::env::temp_dir().join("sparrow_e2e_cli");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn gen_data(dir: &std::path::Path) -> (PathBuf, PathBuf) {
    let train = dir.join("train.sprw");
    let test = dir.join("test.sprw");
    if train.exists() && test.exists() {
        return (train, test);
    }
    let out = bin()
        .args([
            "gen-data",
            "--out",
            train.to_str().unwrap(),
            "--test-out",
            test.to_str().unwrap(),
            "--train-n",
            "20000",
            "--test-n",
            "2000",
            "--features",
            "16",
            "--informative",
            "8",
            "--signal",
            "0.8",
            "--pos-rate",
            "0.2",
        ])
        .output()
        .expect("run gen-data");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    (train, test)
}

#[test]
fn cli_full_pipeline() {
    let dir = workdir();
    let (train, test) = gen_data(&dir);
    let out_dir = dir.join("run1");

    // train
    let out = bin()
        .args([
            "train",
            "--data",
            train.to_str().unwrap(),
            "--test",
            test.to_str().unwrap(),
            "--workers",
            "2",
            "--max-rules",
            "12",
            "--time-limit",
            "30",
            "--out-dir",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .expect("run train");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("trained"), "{stdout}");

    // outputs exist
    for f in ["model.txt", "series.csv", "events.jsonl", "timeline.txt"] {
        assert!(out_dir.join(f).exists(), "missing {f}");
    }

    // eval the saved model
    let out = bin()
        .args([
            "eval",
            "--model",
            out_dir.join("model.txt").to_str().unwrap(),
            "--test",
            test.to_str().unwrap(),
        ])
        .output()
        .expect("run eval");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("exp-loss"), "{stdout}");
    // exp-loss should beat the empty model (1.0)
    let loss: f64 = stdout
        .lines()
        .find(|l| l.starts_with("exp-loss:"))
        .and_then(|l| l.split(':').nth(1))
        .and_then(|v| v.trim().parse().ok())
        .expect("parse loss");
    assert!(loss < 1.0, "loss {loss}");
}

#[test]
fn cli_baseline_runs() {
    let dir = workdir();
    let (train, test) = gen_data(&dir);
    for algo in ["fullscan", "goss", "bulksync"] {
        let out = bin()
            .args([
                "baseline",
                "--algo",
                algo,
                "--data",
                train.to_str().unwrap(),
                "--test",
                test.to_str().unwrap(),
                "--max-rules",
                "6",
                "--time-limit",
                "30",
                "--in-memory",
            ])
            .output()
            .expect("run baseline");
        assert!(
            out.status.success(),
            "{algo}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stdout = String::from_utf8_lossy(&out.stdout);
        assert!(stdout.contains(algo), "{stdout}");
    }
}

#[test]
fn cli_rejects_unknown_args() {
    let out = bin()
        .args(["train", "--data", "x", "--test", "y", "--no-such-flag", "1"])
        .output()
        .expect("run");
    assert!(!out.status.success());
}

#[test]
fn cli_help_lists_commands() {
    let out = bin().output().expect("run");
    let stdout = String::from_utf8_lossy(&out.stdout);
    for cmd in ["gen-data", "train", "baseline", "eval"] {
        assert!(stdout.contains(cmd), "missing {cmd} in usage");
    }
}

#[test]
fn cli_libsvm_conversion() {
    let dir = workdir();
    let svm = dir.join("tiny.svm");
    std::fs::write(&svm, "+1 1:1.5 3:2.0\n-1 2:0.5\n+1 1:0.5 2:1.0 3:0.1\n").unwrap();
    let out_path = dir.join("tiny.sprw");
    let out = bin()
        .args([
            "gen-data",
            "--libsvm",
            svm.to_str().unwrap(),
            "--out",
            out_path.to_str().unwrap(),
        ])
        .output()
        .expect("run gen-data --libsvm");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let store = sparrow::data::DiskStore::open(&out_path).unwrap();
    assert_eq!(store.len(), 3);
    assert_eq!(store.num_features(), 3);
}

#[test]
fn cli_launch_multiprocess_tcp_cluster() {
    let dir = workdir();
    let (train, test) = gen_data(&dir);
    let out_dir = dir.join("launch");
    let out = bin()
        .args([
            "launch",
            "--data",
            train.to_str().unwrap(),
            "--test",
            test.to_str().unwrap(),
            "--workers",
            "2",
            "--base-port",
            "17890",
            "--out-dir",
            out_dir.to_str().unwrap(),
            "--max-rules",
            "8",
            "--time-limit",
            "20",
        ])
        .output()
        .expect("run launch");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("best model"), "{stdout}");
    assert!(out_dir.join("model.txt").exists());
    // both workers produced models + metas
    for i in 0..2 {
        assert!(out_dir.join(format!("worker_{i}.model.txt")).exists());
        assert!(out_dir.join(format!("worker_{i}.model.txt.meta")).exists());
    }
}

#[test]
fn cli_train_resume_roundtrip() {
    let dir = workdir();
    let (train, test) = gen_data(&dir);
    let run1 = dir.join("resume_run1");
    let ok = bin()
        .args([
            "train", "--data", train.to_str().unwrap(), "--test", test.to_str().unwrap(),
            "--workers", "2", "--max-rules", "5", "--time-limit", "20",
            "--out-dir", run1.to_str().unwrap(),
        ])
        .output()
        .expect("run train");
    assert!(ok.status.success(), "{}", String::from_utf8_lossy(&ok.stderr));
    let model_path = run1.join("model.txt");
    let run2 = dir.join("resume_run2");
    let out = bin()
        .args([
            "train", "--data", train.to_str().unwrap(), "--test", test.to_str().unwrap(),
            "--workers", "2", "--max-rules", "10", "--time-limit", "20",
            "--resume", model_path.to_str().unwrap(),
            "--out-dir", run2.to_str().unwrap(),
        ])
        .output()
        .expect("run resumed train");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("resuming from"), "{stdout}");
    // resumed model is at least as long as the checkpoint
    let m1 = std::fs::read_to_string(&model_path).unwrap();
    let m2 = std::fs::read_to_string(run2.join("model.txt")).unwrap();
    let rules = |s: &str| {
        let header = s.lines().next().unwrap();
        header.split_whitespace().last().unwrap().parse::<usize>().unwrap()
    };
    assert!(rules(&m2) >= rules(&m1), "{} -> {}", rules(&m1), rules(&m2));
}
