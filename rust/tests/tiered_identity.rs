//! End-to-end byte-identity of the out-of-core tiered data plane
//! (DESIGN.md §11): a `BackgroundSampler` running over the tiered store
//! must hand the worker the *exact same* samples as one running over the
//! in-memory stratified store, for equal `(seed, stamp, model, store
//! bytes)` — the tier is a placement decision, never a semantic one.

mod common;

use std::path::Path;
use std::time::{Duration, Instant};

use sparrow::config::SamplerKind;
use sparrow::data::{BinSpec, IoThrottle, SampleSet, StrataConfig, TieredConfig};
use sparrow::metrics::EventLog;
use sparrow::model::{StrongRule, Stump};
use sparrow::sampler::{BackgroundSampler, SamplerConfig};

fn cfg(kind: SamplerKind) -> SamplerConfig {
    SamplerConfig {
        target_m: 512,
        kind,
        probe: 512,
        max_passes: 1,
        block: 256,
    }
}

/// A tiered config whose budget forces most of the store onto disk
/// (store below is 20k × 17 f32 ≈ 1.3 MiB; the budget holds ~1/10th).
fn tight_tiered(probe: usize) -> TieredConfig {
    TieredConfig {
        memory_budget: 128 << 10,
        chunk_rows: 512,
        probe_rows: probe,
        readahead_depth: 4,
        relayout_threshold: 0.25,
    }
}

fn spawn_pair(
    path: &Path,
    kind: SamplerKind,
    bin_spec: Option<BinSpec>,
    seed: u64,
) -> (BackgroundSampler, BackgroundSampler) {
    let c = cfg(kind);
    let (log_a, _rx_a) = EventLog::new();
    let (log_b, _rx_b) = EventLog::new();
    let mem = BackgroundSampler::spawn(
        path,
        IoThrottle::unlimited(),
        StrataConfig::default(),
        c.clone(),
        bin_spec.clone(),
        seed,
        0,
        log_a,
    )
    .unwrap();
    let tiered = BackgroundSampler::spawn_tiered(
        path,
        tight_tiered(c.probe),
        c,
        bin_spec,
        seed,
        1,
        log_b,
    )
    .unwrap();
    (mem, tiered)
}

fn build(bg: &mut BackgroundSampler, version: u64, model: &StrongRule) -> SampleSet {
    bg.request(version, model);
    let deadline = Instant::now() + Duration::from_secs(60);
    let (sample, _stats) = bg
        .wait_install(version, || Instant::now() > deadline)
        .unwrap()
        .expect("build timed out");
    sample
}

fn assert_same(a: &SampleSet, b: &SampleSet, what: &str) {
    assert_eq!(a.data, b.data, "{what}: rows differ");
    assert_eq!(a.w_sample, b.w_sample, "{what}: sample weights differ");
    assert_eq!(a.score_sample, b.score_sample, "{what}: scores differ");
    assert_eq!(a.w_last, b.w_last, "{what}: live weights differ");
    assert_eq!(a.score_last, b.score_last, "{what}: live scores differ");
    assert_eq!(
        a.model_len_last, b.model_len_last,
        "{what}: model lengths differ"
    );
    assert_eq!(a.binned, b.binned, "{what}: binned stripes differ");
}

fn model_sequence() -> Vec<StrongRule> {
    let mut m1 = StrongRule::new();
    m1.push(Stump::new(0, 0.0, 1.0), 0.6);
    let mut m2 = m1.clone();
    m2.push(Stump::new(3, 0.2, -1.0), 0.4);
    let mut m3 = m2.clone();
    m3.push(Stump::new(7, -0.1, 1.0), 0.3);
    vec![StrongRule::new(), m1, m2, m3]
}

#[test]
fn tiered_sampler_is_byte_identical_across_model_sequence() {
    let (path, _test) = common::synth_store("sparrow_tiered_ident", 77, 20_000, 16);
    let (mut mem, mut tiered) = spawn_pair(&path, SamplerKind::MinimalVariance, None, 41);
    for (v, model) in model_sequence().into_iter().enumerate() {
        let a = build(&mut mem, v as u64, &model);
        let b = build(&mut tiered, v as u64, &model);
        assert!(!a.is_empty(), "v{v}: empty sample");
        assert_same(&a, &b, &format!("minimal-variance v{v}"));
    }
}

#[test]
fn tiered_sampler_identical_with_prebuilt_stripes() {
    let (path, _test) = common::synth_store("sparrow_tiered_ident", 77, 20_000, 16);
    // a small grid over the first four features
    let nthr = 4;
    let thresholds: Vec<f32> = (0..4)
        .flat_map(|_| vec![-0.5, 0.0, 0.5, 1.0])
        .collect();
    let spec = BinSpec::new((0, 4), nthr, thresholds);
    let (mut mem, mut tiered) =
        spawn_pair(&path, SamplerKind::MinimalVariance, Some(spec.clone()), 19);
    let models = model_sequence();
    let a = build(&mut mem, 1, &models[1]);
    let b = build(&mut tiered, 1, &models[1]);
    assert_same(&a, &b, "binned v1");
    let stripe = b.binned.as_ref().expect("tiered stripe prebuilt");
    assert!(stripe.matches(&spec, b.data.n));
}

#[test]
fn tiered_sampler_identical_for_uniform_kind() {
    let (path, _test) = common::synth_store("sparrow_tiered_ident", 77, 20_000, 16);
    let (mut mem, mut tiered) = spawn_pair(&path, SamplerKind::Uniform, None, 7);
    let models = model_sequence();
    for (v, model) in models.iter().enumerate().take(3) {
        let a = build(&mut mem, v as u64, model);
        let b = build(&mut tiered, v as u64, model);
        assert_same(&a, &b, &format!("uniform v{v}"));
    }
}

#[test]
fn repeat_request_same_version_draws_identical_fresh_coins() {
    // attempt bumps must flow through the tiered path exactly like the
    // in-memory one: a re-request after install draws *different* coins,
    // but the two planes still agree draw-for-draw
    let (path, _test) = common::synth_store("sparrow_tiered_ident", 77, 20_000, 16);
    let (mut mem, mut tiered) = spawn_pair(&path, SamplerKind::MinimalVariance, None, 23);
    let m = &model_sequence()[1];
    let a0 = build(&mut mem, 1, m);
    let b0 = build(&mut tiered, 1, m);
    assert_same(&a0, &b0, "attempt 0");
    let a1 = build(&mut mem, 1, m);
    let b1 = build(&mut tiered, 1, m);
    assert_same(&a1, &b1, "attempt 1");
    assert_ne!(a0.data, a1.data, "attempt bump must change the draw");
}
