//! Control-plane integration: the ISSUE-6 acceptance scenario.
//!
//! During a scripted adoption storm the serve endpoint must answer every
//! prediction (zero drops) with a monotone non-decreasing model version,
//! and a mid-storm `metrics.snapshot` must be consistent with the event
//! log (every counter ≤ what a later drain shows; equal once the storm
//! has quiesced). A second group of tests drives a *real* worker loop
//! through the admin RPC: config nudges, live fault injection, shutdown.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use sparrow::admin::{AdminHandler, ControlState, RpcClient, RpcServer};
use sparrow::metrics::{drain, EventKind, EventLog};
use sparrow::model::{StrongRule, Stump};
use sparrow::serve::{ModelSlot, ServeHandler};
use sparrow::util::json::Json;

/// A model of `n` identical positive stumps on feature 0 — any row with
/// one positive entry is a valid prediction input at every storm version.
fn model_of_len(n: usize) -> StrongRule {
    let mut m = StrongRule::new();
    for _ in 0..n {
        m.push(Stump::new(0, 0.0, 1.0), 0.1);
    }
    m
}

fn params(text: &str) -> Json {
    Json::parse(text).unwrap()
}

#[test]
fn adoption_storm_zero_drops_monotone_versions_consistent_snapshot() {
    const STORM: u64 = 400;

    let state = Arc::new(ControlState::new());
    let slot = Arc::new(ModelSlot::new());
    let stop = Arc::new(AtomicBool::new(false));
    let (log, rx) = EventLog::new();
    let log = log.with_counters(Arc::clone(&state.counters));

    let admin = RpcServer::bind(
        "127.0.0.1:0",
        Arc::new(AdminHandler::new(0, Arc::clone(&state), stop)),
    )
    .unwrap();
    let serve = RpcServer::bind(
        "127.0.0.1:0",
        Arc::new(ServeHandler::new(Arc::clone(&slot))),
    )
    .unwrap();

    // the storm: a scripted trainer adopting/publishing STORM versions
    // back-to-back, feeding gauges, slot and event log exactly like the
    // worker loop's `ControlPlane::note_model` path
    let trainer = {
        let state = Arc::clone(&state);
        let slot = Arc::clone(&slot);
        thread::spawn(move || {
            for v in 1..=STORM {
                let m = model_of_len(v as usize);
                let bound = 1.0 / (v as f64 + 1.0);
                state.note_model(v, m.len(), bound);
                slot.publish(m, v, bound);
                let kind = if v % 3 == 0 {
                    EventKind::LocalImprovement
                } else {
                    EventKind::Accept
                };
                log.record(0, kind, Some((0, v)), bound);
                if v % 32 == 0 {
                    // brief lulls so clients interleave with the storm
                    thread::sleep(Duration::from_micros(200));
                }
            }
        })
    };

    // prediction clients hammer the serve endpoint through the storm;
    // every call must be answered, versions must never go backwards
    let clients: Vec<_> = (0..3)
        .map(|_| {
            let addr = serve.local_addr().to_string();
            thread::spawn(move || {
                let mut c = RpcClient::connect(&addr).unwrap();
                let mut last = 0u64;
                let mut answered = 0u64;
                loop {
                    let r = c
                        .call_ok("predict", params(r#"{"row":[1.5]}"#))
                        .expect("prediction dropped mid-storm");
                    let v = r.get("model_version").and_then(Json::as_u64).unwrap();
                    assert!(v >= last, "served version went backwards: {last} -> {v}");
                    // the served snapshot is internally consistent: score
                    // comes from the same model the version stamp names
                    let score = r.get("score").and_then(Json::as_f64).unwrap();
                    // 0.02 tolerance: f32 alpha accumulation over up to
                    // 400 stumps
                    assert!(
                        (score - 0.1 * v as f64).abs() < 0.02,
                        "version {v} answered with a foreign model (score {score})"
                    );
                    last = v;
                    answered += 1;
                    if v == STORM {
                        break;
                    }
                }
                answered
            })
        })
        .collect();

    // mid-storm admin snapshot: taken while publishes are in flight
    let mut admin_c = RpcClient::connect(&admin.local_addr().to_string()).unwrap();
    let mid = admin_c.call_ok("metrics.snapshot", Json::Null).unwrap();

    trainer.join().unwrap();
    for c in clients {
        let answered = c.join().unwrap();
        assert!(answered > 0, "client never got an answer");
    }

    // quiesced: final snapshot must EQUAL the drained event log, and the
    // mid-storm snapshot must never have exceeded it (bump-after-send)
    let fin = admin_c.call_ok("metrics.snapshot", Json::Null).unwrap();
    let events = drain(&rx);
    for k in EventKind::ALL {
        let name = k.as_str();
        let drained = events.iter().filter(|e| e.kind == k).count() as u64;
        let count = |snap: &Json| {
            snap.get("events")
                .and_then(|e| e.get(name))
                .and_then(Json::as_u64)
                .unwrap_or_else(|| panic!("snapshot missing {name}"))
        };
        assert_eq!(count(&fin), drained, "final snapshot vs drain for {name}");
        assert!(count(&mid) <= drained, "mid-storm snapshot exceeds drain for {name}");
    }
    assert_eq!(
        events.len() as u64,
        STORM,
        "storm events lost between log and drain"
    );

    // gauges, serve slot and serve.stats all agree on the final version
    assert_eq!(slot.version(), STORM);
    let model = fin.get("model").unwrap();
    assert_eq!(model.get("version").and_then(Json::as_u64), Some(STORM));
    assert_eq!(model.get("len").and_then(Json::as_u64), Some(STORM));
    let mut serve_c = RpcClient::connect(&serve.local_addr().to_string()).unwrap();
    let stats = serve_c.call_ok("serve.stats", Json::Null).unwrap();
    assert_eq!(stats.get("model_version").and_then(Json::as_u64), Some(STORM));
    assert!(stats.get("swaps").and_then(Json::as_u64).unwrap() <= STORM);
    assert!(stats.get("predictions").and_then(Json::as_u64).unwrap() > 0);
}

#[test]
fn seeded_slot_serves_checkpoint_until_first_adoption() {
    use sparrow::admin::RpcHandler;
    // `sparrow serve --resume`: the checkpoint is served at version 0 and
    // the first live adoption (version 1) hot-swaps over it
    let slot = Arc::new(ModelSlot::new());
    slot.seed(model_of_len(3), 0.5);
    let h = ServeHandler::new(Arc::clone(&slot));
    let r = h.handle("predict", &params(r#"{"row":[1.0]}"#)).unwrap();
    assert_eq!(r.get("model_version").and_then(Json::as_u64), Some(0));
    assert!((r.get("score").and_then(Json::as_f64).unwrap() - 0.3).abs() < 1e-3);
    slot.publish(model_of_len(4), 1, 0.4);
    let r = h.handle("predict", &params(r#"{"row":[1.0]}"#)).unwrap();
    assert_eq!(r.get("model_version").and_then(Json::as_u64), Some(1));
}

// ---- real worker loop under admin control --------------------------------

mod live_worker {
    use super::*;
    use sparrow::boosting::grid::partition_features;
    use sparrow::boosting::CandidateGrid;
    use sparrow::config::TrainConfig;
    use sparrow::data::{DiskStore, IoThrottle};
    use sparrow::scanner::NativeBackend;
    use sparrow::worker::{run_worker, ControlPlane, NullLink, WorkerParams};

    /// A single-worker setup (NullLink transport) with the control plane
    /// attached — the library-level equivalent of `sparrow serve` with a
    /// generous rule/time budget, so only the admin RPC ends the run.
    fn worker_with_control() -> (
        WorkerParams,
        Arc<ControlState>,
        Arc<ModelSlot>,
        Arc<AtomicBool>,
    ) {
        let (path, _test) = common::synth_store("sparrow_control_plane", 5, 4_000, 100);
        let store = DiskStore::open(&path).unwrap();
        let features = store.num_features();
        let pilot = store
            .stream(IoThrottle::unlimited())
            .unwrap()
            .next_block(2048)
            .unwrap();
        let grid = CandidateGrid::from_quantiles(&pilot, 4);
        let stripe = partition_features(features, 1)[0];
        let cfg = TrainConfig {
            num_workers: 1,
            sample_size: 512,
            max_rules: 10_000,
            time_limit: Duration::from_secs(30),
            ..TrainConfig::default()
        };
        let state = Arc::new(ControlState::new());
        let slot = Arc::new(ModelSlot::new());
        let stop = Arc::new(AtomicBool::new(false));
        let (log, _rx) = EventLog::new();
        let log = log.with_counters(Arc::clone(&state.counters));
        let params = WorkerParams {
            id: 0,
            cfg,
            grid,
            stripe,
            store,
            endpoint: Box::new(NullLink),
            log,
            stop: Arc::clone(&stop),
            backend: Box::new(NativeBackend),
            laggard: 1.0,
            crash_after: None,
            seed: 11,
            control: Some(ControlPlane {
                state: Arc::clone(&state),
                slot: Arc::clone(&slot),
            }),
        };
        (params, state, slot, stop)
    }

    /// Poll `model.current` until the worker has published `version >= v`
    /// (bounded wait — the synth store certifies rules in milliseconds).
    fn wait_for_version(c: &mut RpcClient, v: u64) -> u64 {
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            let cur = c
                .call_ok("model.current", Json::Null)
                .unwrap()
                .get("version")
                .and_then(Json::as_u64)
                .unwrap();
            if cur >= v {
                return cur;
            }
            assert!(Instant::now() < deadline, "worker never reached version {v}");
            thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn shutdown_rpc_stops_a_live_worker_after_nudges() {
        let (params, state, slot, stop) = worker_with_control();
        let admin = RpcServer::bind(
            "127.0.0.1:0",
            Arc::new(AdminHandler::new(0, Arc::clone(&state), stop)),
        )
        .unwrap();
        let worker = thread::spawn(move || run_worker(params));
        let mut c = RpcClient::connect(&admin.local_addr().to_string()).unwrap();

        // let training make real progress, then steer it over RPC
        wait_for_version(&mut c, 1);
        c.call_ok("config.set_gamma", params_json(r#"{"gamma":0.05}"#)).unwrap();
        c.call_ok("config.gamma_reset", Json::Null).unwrap();
        c.call_ok("fault.inject", params_json(r#"{"fault":"laggard","factor":2}"#))
            .unwrap();
        c.call_ok("fault.inject", params_json(r#"{"fault":"heal"}"#)).unwrap();
        wait_for_version(&mut c, 2);

        let r = c.call_ok("shutdown", Json::Null).unwrap();
        assert_eq!(r.get("stopping").and_then(Json::as_bool), Some(true));
        let result = worker.join().unwrap();
        assert!(!result.crashed, "clean shutdown must not count as a crash");
        assert!(result.model.len() >= 2);

        // gauges and the serve slot reflect the final model exactly
        let (version, len, _bound) = state.model();
        assert_eq!(len as usize, result.model.len());
        assert_eq!(slot.version(), version);
        assert_eq!(slot.current().model.len(), result.model.len());
        let snap = c.call_ok("metrics.snapshot", Json::Null).unwrap();
        assert!(snap.get("scanned").and_then(Json::as_u64).unwrap() > 0);
        assert!(
            snap.get("events")
                .and_then(|e| e.get("local_improvement"))
                .and_then(Json::as_u64)
                .unwrap()
                > 0
        );
    }

    #[test]
    fn crash_injection_via_rpc_marks_worker_crashed() {
        let (params, state, _slot, stop) = worker_with_control();
        let admin = RpcServer::bind(
            "127.0.0.1:0",
            Arc::new(AdminHandler::new(0, Arc::clone(&state), stop)),
        )
        .unwrap();
        let worker = thread::spawn(move || run_worker(params));
        let mut c = RpcClient::connect(&admin.local_addr().to_string()).unwrap();
        c.call_ok("fault.inject", params_json(r#"{"fault":"crash"}"#)).unwrap();
        let result = worker.join().unwrap();
        assert!(result.crashed, "crash injection must mark the result");
        assert_eq!(state.counters.get(EventKind::Crash), 1);
    }

    fn params_json(text: &str) -> Json {
        Json::parse(text).unwrap()
    }
}
