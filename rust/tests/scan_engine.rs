//! `--scan-engine` equivalence suite (DESIGN.md §8): the binned columnar
//! engine must not change a single certified answer — identical
//! `ScanOutcome` (same stump, same γ, same scanned count) as the row
//! engine on the fixed-seed cluster-integration fixtures, for every
//! thread count — while the whole pipeline (worker, sampler modes,
//! cluster) keeps running.

mod common;

use std::time::Duration;

use sparrow::boosting::{alpha_for_advantage, grid::partition_features, CandidateGrid};
use sparrow::config::{SamplerMode, ScanEngine, TrainConfig};
use sparrow::coordinator::train_cluster;
use sparrow::data::{DiskStore, IoThrottle, SampleSet};
use sparrow::model::StrongRule;
use sparrow::sampler::{Sampler, SamplerConfig};
use sparrow::scanner::{BinnedBackend, NativeBackend, ScanBackend, ScanOutcome, Scanner, ScannerConfig};
use sparrow::stopping::LilRule;
use sparrow::util::rng::Rng;

/// The cluster-integration fixture: store + pilot-quantile grid, exactly
/// as `coordinator::train_cluster` derives them.
fn fixture(nthr: usize) -> (std::path::PathBuf, CandidateGrid) {
    let (path, _test) = common::synth_store("sparrow_scan_engine", 99, 20_000, 2_000);
    let store = DiskStore::open(&path).unwrap();
    let pilot = store
        .stream(IoThrottle::unlimited())
        .unwrap()
        .next_block(4096.min(store.len()))
        .unwrap();
    (path.clone(), CandidateGrid::from_quantiles(&pilot, nthr))
}

/// A fixed-seed blocking resample against `model` — byte-identical on
/// every call with the same seed.
fn fixture_sample(path: &std::path::Path, m: usize, seed: u64, model: &StrongRule) -> SampleSet {
    let store = DiskStore::open(path).unwrap();
    let mut sampler = Sampler::new(
        store.stream(IoThrottle::unlimited()).unwrap(),
        store.len(),
        SamplerConfig {
            target_m: m,
            ..SamplerConfig::default()
        },
        Rng::new(seed),
    );
    sampler.resample(model).unwrap().0
}

fn scanner_with(grid: CandidateGrid, stripe: (usize, usize), backend: Box<dyn ScanBackend>) -> Scanner {
    Scanner::new(
        grid,
        stripe,
        backend,
        Box::new(LilRule::default()),
        ScannerConfig {
            batch: 128,
            gamma0: 0.2,
            gamma_min: 0.001,
            scan_budget: 0,
            sweep_every: 0,
        },
    )
}

/// Drive one engine through `iters` boosting iterations over the fixture:
/// resample (fixed seed) whenever a pass exhausts, push certified stumps,
/// and record every outcome.
fn drive(
    path: &std::path::Path,
    grid: &CandidateGrid,
    stripe: (usize, usize),
    backend: Box<dyn ScanBackend>,
    iters: usize,
) -> (Vec<ScanOutcome>, Vec<f32>, StrongRule) {
    let mut sc = scanner_with(grid.clone(), stripe, backend);
    let mut model = StrongRule::new();
    let mut sample = fixture_sample(path, 2048, 7, &model);
    let mut outcomes = Vec::new();
    for _ in 0..iters {
        let out = sc.run_pass(&mut sample, &model, || false);
        outcomes.push(out.clone());
        match out {
            ScanOutcome::Found { stump, gamma, .. } => {
                model.push(stump, alpha_for_advantage(gamma) as f32);
            }
            ScanOutcome::Exhausted { .. } => {
                // Alg. 2 Fail → fresh fixed-seed sample against the model
                sample = fixture_sample(path, 2048, 7 + model.len() as u64, &model);
                sc.reset_cursor();
            }
            ScanOutcome::Interrupted { .. } => unreachable!("no interrupts"),
        }
    }
    (outcomes, sample.w_last, model)
}

#[test]
fn binned_outcomes_identical_to_rows_on_fixture() {
    // acceptance: --scan-engine binned produces the identical ScanOutcome
    // (stump, γ, scanned) as rows on the fixed-seed fixture, for thread
    // counts 1 and 4 — across a whole model-evolution run, not one pass
    let (path, grid) = fixture(4);
    let stripe = partition_features(grid.f, 4)[1]; // a real worker stripe
    let (rows, rows_w, rows_model) =
        drive(&path, &grid, stripe, Box::new(NativeBackend), 6);
    assert!(
        rows.iter()
            .any(|o| matches!(o, ScanOutcome::Found { .. })),
        "fixture must certify something: {rows:?}"
    );
    for threads in [1usize, 4] {
        let (binned, binned_w, binned_model) = drive(
            &path,
            &grid,
            stripe,
            Box::new(BinnedBackend::new(threads)),
            6,
        );
        assert_eq!(rows, binned, "outcomes diverged at threads={threads}");
        assert_eq!(rows_w, binned_w, "weights diverged at threads={threads}");
        assert_eq!(
            rows_model.to_text(),
            binned_model.to_text(),
            "models diverged at threads={threads}"
        );
    }
}

#[test]
fn binned_full_width_stripe_matches_rows() {
    // single-worker shape: the full feature width in one stripe
    let (path, grid) = fixture(4);
    let stripe = (0, grid.f);
    let (rows, _, _) = drive(&path, &grid, stripe, Box::new(NativeBackend), 4);
    let (binned, _, _) = drive(&path, &grid, stripe, Box::new(BinnedBackend::new(4)), 4);
    assert_eq!(rows, binned);
}

fn cluster_cfg() -> TrainConfig {
    TrainConfig {
        num_workers: 4,
        sample_size: 2048,
        max_rules: 10,
        time_limit: Duration::from_secs(30),
        gamma0: 0.2,
        scan_engine: ScanEngine::Binned,
        scan_threads: 2,
        ..TrainConfig::default()
    }
}

#[test]
fn binned_cluster_run_learns() {
    // end-to-end: a 4-worker cluster on the binned engine (worker prebuilds
    // bins at install time) makes normal progress
    let (path, test) = common::synth_store("sparrow_scan_engine", 99, 20_000, 2_000);
    let cfg = cluster_cfg();
    let threads = cfg.scan_threads;
    let out = train_cluster(&cfg, &path, &test, "binned", &move |_| {
        Ok(Box::new(BinnedBackend::new(threads)) as Box<dyn ScanBackend>)
    })
    .unwrap();
    assert!(!out.model.is_empty(), "no rules learned on binned engine");
    assert!(out.workers.iter().all(|w| !w.crashed));
    assert!(out.loss_bound < 1.0, "bound {}", out.loss_bound);
}

#[test]
fn binned_cluster_run_learns_with_background_sampler() {
    // the builder-thread commit path prebuilds the stripe view; the swap
    // hands it over and the scanner never bins on the hot path
    let (path, test) = common::synth_store("sparrow_scan_engine", 99, 20_000, 2_000);
    let mut cfg = cluster_cfg();
    cfg.sampler_mode = SamplerMode::Background;
    // batch > BIN_CHUNK so the scoped-thread sharding actually engages in
    // a real cluster run (at batch ≤ 512 a batch is a single chunk)
    cfg.batch = 1024;
    let threads = cfg.scan_threads;
    let out = train_cluster(&cfg, &path, &test, "binned-bg", &move |_| {
        Ok(Box::new(BinnedBackend::new(threads)) as Box<dyn ScanBackend>)
    })
    .unwrap();
    assert!(!out.model.is_empty());
    assert!(out.workers.iter().all(|w| !w.crashed));
}
