//! Golden-schema tests pinning the admin/serve RPC wire format.
//!
//! Every method in `ADMIN_METHODS` / `SERVE_METHODS` has a stored
//! request/response fixture pair under `tests/golden/admin_rpc/`; this
//! suite replays each request through the socket-free [`dispatch`] core
//! against a fully deterministic handler (SimClock uptime, scripted
//! gauges) and compares the response **byte-for-byte**. Any wire-format
//! drift — key renames, number formatting, error codes or messages —
//! fails tier-1. If the change is intentional, regenerate with
//! `GOLDEN_REGEN=1 cargo test --test admin_schema` and update
//! OPERATIONS.md to match.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use sparrow::admin::{
    dispatch, AdminHandler, ChaosCtl, ControlState, RpcHandler, ADMIN_METHODS, SERVE_METHODS,
};
use sparrow::metrics::EventKind;
use sparrow::model::{StrongRule, Stump};
use sparrow::network::chaos::ChaosRules;
use sparrow::network::tcp::PeerInfo;
use sparrow::serve::{ModelSlot, ServeHandler};
use sparrow::sim::SimClock;

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/admin_rpc")
}

/// The scripted admin-side state every `admin_*` fixture is computed
/// against: 2 s of SimClock uptime, model v3 (3 rules, bound 0.5),
/// 1000 examples scanned, 250 ms of sampler stall, a 2/1/1
/// accept/reject/local-improvement counter history, a two-row static
/// peer table (one up, one down), and a chaos fabric with two directed
/// edges (so `fault.inject partition` succeeds on the real path).
fn admin_fixture_handler() -> AdminHandler {
    let clock = Arc::new(SimClock::new());
    let state = Arc::new(ControlState::with_clock(clock.clone()));
    state.note_model(3, 3, 0.5);
    state.note_scanned(1000);
    state.add_stall(Duration::from_millis(250));
    state.counters.bump(EventKind::Accept);
    state.counters.bump(EventKind::Accept);
    state.counters.bump(EventKind::Reject);
    state.counters.bump(EventKind::LocalImprovement);
    state.set_peer_source(Arc::new(|| {
        vec![
            PeerInfo {
                addr: "127.0.0.1:7701".into(),
                up: true,
                queue_len: 3,
                last_seen_ms: 150,
                reconnects: 1,
                drops: 0,
            },
            PeerInfo {
                addr: "127.0.0.1:7702".into(),
                up: false,
                queue_len: 17,
                last_seen_ms: 4200,
                reconnects: 6,
                drops: 12,
            },
        ]
    }));
    state.set_chaos(ChaosCtl {
        rules: ChaosRules::new(0),
        edges: vec!["w0->w1".into(), "w1->w0".into()],
    });
    clock.advance(Duration::from_secs(2));
    AdminHandler::new(0, state, Arc::new(AtomicBool::new(false)))
}

/// The scripted serve-side state for `serve_*` fixtures: one published
/// model (v1, a single +1-above-0 stump on feature 0 with α = 0.5,
/// bound 0.75). Request counters advance as the fixtures replay in
/// filename order, which is why the fixtures are numbered.
fn serve_fixture_handler() -> ServeHandler {
    let slot = Arc::new(ModelSlot::new());
    let mut m = StrongRule::new();
    m.push(Stump::new(0, 0.0, 1.0), 0.5);
    slot.publish(m, 1, 0.75);
    ServeHandler::new(slot)
}

/// Replay every `<prefix>*.request.json` (sorted, so numbering fixes the
/// order stateful counters advance in) and diff against the stored
/// response. `GOLDEN_REGEN=1` rewrites the response files instead.
fn replay(prefix: &str, handler: &dyn RpcHandler) {
    let dir = golden_dir();
    let mut cases: Vec<PathBuf> = fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("missing fixture dir {}: {e}", dir.display()))
        .map(|e| e.unwrap().path())
        .filter(|p| {
            let name = p.file_name().unwrap().to_str().unwrap();
            name.starts_with(prefix) && name.ends_with(".request.json")
        })
        .collect();
    cases.sort();
    assert!(!cases.is_empty(), "no {prefix} fixtures in {}", dir.display());
    for req_path in cases {
        let resp_path = PathBuf::from(
            req_path
                .to_str()
                .unwrap()
                .replace(".request.json", ".response.json"),
        );
        let request = fs::read_to_string(&req_path).unwrap();
        let got = String::from_utf8(dispatch(handler, request.trim_end().as_bytes())).unwrap();
        if std::env::var_os("GOLDEN_REGEN").is_some() {
            fs::write(&resp_path, format!("{got}\n")).unwrap();
            continue;
        }
        let want = fs::read_to_string(&resp_path)
            .unwrap_or_else(|_| panic!("missing {}", resp_path.display()));
        assert_eq!(
            got,
            want.trim_end(),
            "RPC wire format drifted for {} — if intentional, regenerate with \
             GOLDEN_REGEN=1 and update OPERATIONS.md",
            req_path.display()
        );
    }
}

#[test]
fn admin_wire_format_pinned() {
    replay("admin_", &admin_fixture_handler());
}

#[test]
fn serve_wire_format_pinned() {
    replay("serve_", &serve_fixture_handler());
}

#[test]
fn every_method_has_a_fixture() {
    // the canonical method lists are the coverage contract: adding a
    // method without pinning its wire format fails here
    for (prefix, methods) in [("admin_", ADMIN_METHODS), ("serve_", SERVE_METHODS)] {
        let mut blob = String::new();
        for e in fs::read_dir(golden_dir()).unwrap() {
            let p = e.unwrap().path();
            let name = p.file_name().unwrap().to_str().unwrap();
            if name.starts_with(prefix) && name.ends_with(".request.json") {
                blob.push_str(&fs::read_to_string(&p).unwrap());
                blob.push('\n');
            }
        }
        for m in methods {
            assert!(
                blob.contains(&format!("\"method\":\"{m}\"")),
                "no golden fixture for {prefix}{m}"
            );
        }
    }
}
