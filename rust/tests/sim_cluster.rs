//! Deterministic fault-injection scenario suite (DESIGN.md §9).
//!
//! Every test here runs the **real** TMSN state machine over the seeded
//! virtual-time simulator and asserts the paper's resilience claims as
//! invariants:
//!
//! * accept-iff-strictly-better is never violated,
//! * certificates are monotone per worker (per incarnation),
//! * the cluster converges despite k-of-n crashes,
//! * laggards never block peers (the no-barrier claim),
//! * and a fixed seed yields a **byte-identical** event trace.
//!
//! The suite honors `SPARROW_SIM_SEED` (default 1): CI runs it across
//! several seeds (`.github/workflows/ci.yml`, job `sim`; locally
//! `make sim` or `SPARROW_SIM_SEED=7 cargo test --test sim_cluster`).

use std::sync::Arc;
use std::time::Duration;

use sparrow::metrics::{EventKind, EventLog};
use sparrow::sgd::SgdPayload;
use sparrow::sim::{
    preset, run_scenario, sgd_sim_fixture, BoostSimWorker, EdgeFaults, Scenario, ScenarioEvent,
    SgdSimWorker, SimClock, SimConfig, SimNet, SimNetConfig, SimReport, PRESETS,
};
use sparrow::tmsn::{BoostPayload, Certified, Driver, Payload, Tmsn};

fn ms(x: u64) -> Duration {
    Duration::from_millis(x)
}

/// The seed CI sweeps via the `SPARROW_SIM_SEED` matrix.
fn env_seed() -> u64 {
    std::env::var("SPARROW_SIM_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

fn boost_cfg(seed: u64, scenario: Scenario) -> SimConfig {
    SimConfig {
        workers: 5,
        seed,
        scenario,
        horizon: ms(1500),
        ..SimConfig::default()
    }
}

fn run_boost(cfg: &SimConfig) -> SimReport<BoostPayload> {
    // the canonical (run seed, worker, incarnation) derivation, shared
    // with `sparrow sim`, so restarts are deterministic too
    run_scenario(cfg, |id, incarnation| BoostSimWorker::for_run(cfg.seed, id, incarnation))
}

fn run_sgd(cfg: &SimConfig) -> SimReport<SgdPayload> {
    let (shards, valid) = sgd_sim_fixture(cfg.seed, cfg.workers);
    run_scenario(cfg, |id, _incarnation| {
        // a restarted machine re-reads the same on-disk shard but starts
        // from zero weights — SgdSimWorker::new is already that state
        SgdSimWorker::new(id, Arc::clone(&shards[id]), Arc::clone(&valid))
    })
}

fn assert_clean<P: Payload>(r: &SimReport<P>) {
    assert!(
        r.violations.is_empty(),
        "TMSN invariant violations:\n{}",
        r.violations.join("\n")
    );
}

// ---------------------------------------------------------------------------
// determinism: the acceptance criterion (byte-identical traces per seed)
// ---------------------------------------------------------------------------

#[test]
fn fixed_seed_gives_byte_identical_traces_for_every_preset() {
    let seed = env_seed();
    for name in PRESETS {
        let scenario = preset(name, 5).expect(name);
        let a = run_boost(&boost_cfg(seed, scenario.clone()));
        let b = run_boost(&boost_cfg(seed, scenario));
        assert_clean(&a);
        assert!(!a.trace.is_empty());
        assert_eq!(
            a.trace, b.trace,
            "trace of preset '{name}' is not a pure function of seed {seed}"
        );
        // the virtual timeline and counters replay exactly too
        assert_eq!(a.virtual_elapsed, b.virtual_elapsed);
        assert_eq!(a.net, b.net);
    }
}

#[test]
fn different_seeds_give_different_traces() {
    let scenario = preset("crash", 5).unwrap();
    let a = run_boost(&boost_cfg(1, scenario.clone()));
    let b = run_boost(&boost_cfg(2, scenario));
    assert_ne!(a.trace, b.trace, "the seed must actually steer the run");
}

#[test]
fn sgd_trace_is_byte_identical_per_seed() {
    let cfg = SimConfig {
        workers: 4,
        seed: env_seed(),
        scenario: preset("churn", 4).unwrap(),
        horizon: ms(1500),
        ..SimConfig::default()
    };
    let a = run_sgd(&cfg);
    let b = run_sgd(&cfg);
    assert_clean(&a);
    assert_eq!(a.trace, b.trace, "SGD trace is not a pure function of the seed");
}

// ---------------------------------------------------------------------------
// crash resilience: convergence despite k-of-n failures
// ---------------------------------------------------------------------------

#[test]
fn boosting_converges_despite_k_of_n_crashes() {
    let r = run_boost(&boost_cfg(env_seed(), preset("crash", 5).unwrap()));
    assert_clean(&r);
    let crashed: Vec<usize> =
        r.workers.iter().filter(|w| !w.alive).map(|w| w.id).collect();
    assert_eq!(crashed, vec![3, 4], "the crash preset fells the top 2 of 5");
    // survivors made certified progress and all ended on the best bound
    assert!(r.best.cert.loss_bound < 0.5, "bound {}", r.best.cert.loss_bound);
    assert!(r.survivors_converged(), "survivors diverged: {:?}", r.workers);
    // crashed workers stopped working (strictly fewer steps than peers)
    for &c in &crashed {
        assert!(r.workers[c].steps < r.workers[0].steps);
    }
    // the metrics pipeline saw the crashes, on the virtual clock
    let crash_events: Vec<_> =
        r.events.iter().filter(|e| e.kind == EventKind::Crash).collect();
    assert_eq!(crash_events.len(), 2);
    assert!(crash_events.iter().all(|e| e.elapsed >= ms(300)));
}

#[test]
fn restart_rejoins_with_nothing_but_broadcasts() {
    // churn preset: worker 1 crashes at 300ms and restarts at 900ms with
    // an empty model; by quiescence it must hold the best certificate —
    // the paper's "no recovery ceremony" claim.
    let r = run_boost(&boost_cfg(env_seed(), preset("churn", 5).unwrap()));
    assert_clean(&r);
    assert_eq!(r.workers[1].restarts, 1);
    assert!(r.workers[1].alive);
    assert!(!r.workers[4].alive, "churn crashes the last worker for good");
    assert!(r.survivors_converged(), "{:?}", r.workers);
    assert!(r.trace.contains("w1   restart"));
}

// ---------------------------------------------------------------------------
// laggards: the no-barrier claim
// ---------------------------------------------------------------------------

#[test]
fn laggard_never_blocks_peers() {
    let seed = env_seed();
    let base = run_boost(&boost_cfg(seed, preset("calm", 5).unwrap()));
    let lag = run_boost(&boost_cfg(seed, preset("laggard", 5).unwrap()));
    assert_clean(&base);
    assert_clean(&lag);
    // worker 1 is 8x slower from t=100ms; every other worker's work
    // schedule is *bit-identical* to the fault-free run — there is no
    // barrier anywhere for a slow machine to hold
    for id in [0usize, 2, 3, 4] {
        assert_eq!(
            base.workers[id].steps, lag.workers[id].steps,
            "laggard changed peer {id}'s step count"
        );
        assert_eq!(
            base.workers[id].published, lag.workers[id].published,
            "laggard changed peer {id}'s publish count"
        );
    }
    // the laggard itself does proportionally less
    assert!(lag.workers[1].steps < base.workers[1].steps / 3);
    // and still converges with everyone else
    assert!(lag.survivors_converged());
}

// ---------------------------------------------------------------------------
// partitions
// ---------------------------------------------------------------------------

#[test]
fn partition_heals_and_cluster_reconverges() {
    let r = run_boost(&boost_cfg(env_seed(), preset("partition", 5).unwrap()));
    assert_clean(&r);
    assert!(r.net.partition_blocked > 0, "partition never blocked anything");
    assert!(r.survivors_converged(), "cluster did not reconverge after heal");
    assert!(r.trace.contains("net  partition"));
    assert!(r.trace.contains("net  heal"));
}

#[test]
fn unhealed_partition_converges_per_island() {
    // without a heal, each island must still satisfy every invariant and
    // converge internally (global convergence is impossible by design)
    let scenario = Scenario::new().at(
        ms(100),
        ScenarioEvent::Partition(vec![vec![0, 1], vec![2, 3, 4]]),
    );
    let r = run_boost(&boost_cfg(env_seed(), scenario));
    assert_clean(&r);
    for island in [vec![0usize, 1], vec![2usize, 3, 4]] {
        let best = island
            .iter()
            .map(|&i| r.workers[i].final_summary)
            .fold(f64::INFINITY, f64::min);
        for &i in &island {
            assert_eq!(
                r.workers[i].final_summary, best,
                "island {island:?} did not converge internally"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// chaotic wire: drop + duplication + reordering
// ---------------------------------------------------------------------------

#[test]
fn lossy_duplicating_reordering_links_preserve_all_invariants() {
    let cfg = SimConfig {
        workers: 5,
        seed: env_seed() ^ 0xC405,
        net: SimNetConfig {
            edge: EdgeFaults::lossy(0.25, 0.25, 0.5),
            overrides: Vec::new(),
        },
        scenario: preset("churn", 5).unwrap(),
        horizon: ms(1500),
        ..SimConfig::default()
    };
    let r = run_boost(&cfg);
    // the whole point: duplicated/reordered/stale deliveries are rejected
    // by the verdict rule, never adopted — zero invariant violations
    assert_clean(&r);
    let s = &r.net;
    assert!(s.dropped > 0 && s.duplicated > 0 && s.reordered > 0, "{s:?}");
    // wire accounting: every offered message is delivered, dropped,
    // blocked, or discarded at a dead node; duplicates add deliveries
    assert_eq!(
        s.delivered + s.to_down,
        s.offered - s.dropped - s.partition_blocked + s.duplicated,
        "{s:?}"
    );
    // duplicates of an adopted payload must show up as rejects
    assert!(r.workers.iter().map(|w| w.rejects).sum::<u64>() > 0);
}

// ---------------------------------------------------------------------------
// SGD workload: the same engine carries a second learner
// ---------------------------------------------------------------------------

#[test]
fn sgd_converges_despite_crashes() {
    // crash-only scenario: every survivor sees every broadcast, so exact
    // convergence to the best certificate is structurally guaranteed —
    // even if the best publisher is one of the machines that later dies
    let cfg = SimConfig {
        workers: 4,
        seed: env_seed(),
        scenario: preset("crash", 4).unwrap(),
        horizon: ms(1500),
        ..SimConfig::default()
    };
    let r = run_sgd(&cfg);
    assert_clean(&r);
    assert!(
        r.best.cert.loss < std::f64::consts::LN_2,
        "certified loss {} not below the zero model",
        r.best.cert.loss
    );
    assert_eq!(r.workers.iter().filter(|w| !w.alive).count(), 2);
    assert!(r.survivors_converged(), "{:?}", r.workers);
    // someone adopted someone else's model (the protocol did its job)
    assert!(r.workers.iter().map(|w| w.accepts).sum::<u64>() > 0);
}

#[test]
fn sgd_survives_churn_and_restart_recovers() {
    let cfg = SimConfig {
        workers: 4,
        seed: env_seed(),
        scenario: preset("churn", 4).unwrap(),
        horizon: ms(1500),
        ..SimConfig::default()
    };
    let r = run_sgd(&cfg);
    assert_clean(&r);
    assert!(r.best.cert.loss < std::f64::consts::LN_2);
    // the restarted worker rebuilt from zero weights (plus any broadcasts
    // it heard) and must itself end with a certified sub-ln2 model; note
    // TMSN promises *progress*, not late-joiner state sync — if every
    // peer plateaued under the ε gap before the restart, nothing obliges
    // them to re-broadcast, so exact equality is not asserted here
    // (see sgd_converges_despite_crashes for the exact-convergence case)
    let w1 = &r.workers[1];
    assert_eq!((w1.restarts, w1.alive), (1, true));
    assert!(
        w1.final_summary < std::f64::consts::LN_2,
        "restarted worker never recovered: {w1:?}"
    );
    assert!(r.workers.iter().map(|w| w.accepts).sum::<u64>() > 0);
    assert!(r.trace.contains("w1   restart"));
}

// ---------------------------------------------------------------------------
// the production Driver runs unmodified over SimNet + SimClock
// ---------------------------------------------------------------------------

#[test]
fn driver_runs_unmodified_over_simnet_under_virtual_time() {
    let clock = Arc::new(SimClock::new());
    let (log, rx) = EventLog::with_clock(clock.clone());
    let delay = EdgeFaults {
        delay_min: ms(5),
        delay_max: ms(5),
        ..EdgeFaults::default()
    };
    let cfg = SimNetConfig {
        edge: delay,
        overrides: Vec::new(),
    };
    let (net, mut eps) = SimNet::<BoostPayload>::new(2, cfg, sparrow::util::rng::Rng::new(3));
    let b_ep = eps.pop().unwrap();
    let a_ep = eps.pop().unwrap();
    let mut a = Driver::new(Tmsn::<BoostPayload>::new(0), a_ep, log.clone());
    let mut b = Driver::new(Tmsn::<BoostPayload>::new(1), b_ep, log);

    // a real local improvement through the production send path
    let mut model = a.payload().model.clone();
    model.push(sparrow::model::Stump::new(0, 0.0, 1.0), 0.2);
    let improved = a.payload().improved(model, 0.1);
    a.publish(improved);

    // nothing arrives until virtual time reaches the link delay
    assert_eq!(b.poll_adopt(&mut |_, _| {}), 0);
    assert_eq!(net.next_due(), Some(ms(5)));
    clock.advance_to(ms(5));
    net.deliver_due(ms(5));
    assert_eq!(b.poll_adopt(&mut |_, _| {}), 1, "driver must adopt over SimNet");
    assert_eq!(b.cert().origin, 0);
    assert!(b.cert().loss_bound < 1.0);

    // the unmodified metrics pipeline stamped *virtual* time
    let events = sparrow::metrics::drain(&rx);
    let accept = events
        .iter()
        .find(|e| e.kind == EventKind::Accept)
        .expect("accept event");
    assert_eq!(accept.elapsed, ms(5), "accept must be stamped at virtual t=5ms");
}

// ---------------------------------------------------------------------------
// the full battery on the CI seed matrix
// ---------------------------------------------------------------------------

#[test]
fn seeded_battery_all_presets_hold_all_invariants() {
    let seed = env_seed();
    for name in PRESETS {
        let r = run_boost(&boost_cfg(seed, preset(name, 5).expect(name)));
        assert_clean(&r);
        assert!(
            r.best.cert().summary() < 1.0,
            "preset '{name}' made no certified progress"
        );
        assert!(
            r.survivors_converged(),
            "preset '{name}' (seed {seed}) did not converge: {:?}",
            r.workers
        );
    }
}
