//! Deterministic fault-injection scenario suite (DESIGN.md §9).
//!
//! Every test here runs the **real** TMSN state machine over the seeded
//! virtual-time simulator and asserts the paper's resilience claims as
//! invariants:
//!
//! * accept-iff-strictly-better is never violated,
//! * certificates are monotone per worker (per incarnation),
//! * the cluster converges despite k-of-n crashes,
//! * laggards never block peers (the no-barrier claim),
//! * and a fixed seed yields a **byte-identical** event trace.
//!
//! The suite honors `SPARROW_SIM_SEED` (default 1): CI runs it across
//! several seeds (`.github/workflows/ci.yml`, job `sim`; locally
//! `make sim` or `SPARROW_SIM_SEED=7 cargo test --test sim_cluster`).

use std::sync::Arc;
use std::time::Duration;

use sparrow::metrics::{EventKind, EventLog};
use sparrow::network::BroadcastMode;
use sparrow::sgd::SgdPayload;
use sparrow::sim::{
    preset, run_scenario, sgd_sim_fixture, BoostSimWorker, EdgeFaults, Scenario, ScenarioEvent,
    SgdSimWorker, SimClock, SimConfig, SimNet, SimNetConfig, SimReport, PRESETS,
};
use sparrow::tmsn::{BoostPayload, Certified, Driver, Payload, Tmsn};

fn ms(x: u64) -> Duration {
    Duration::from_millis(x)
}

/// The seed CI sweeps via the `SPARROW_SIM_SEED` matrix.
fn env_seed() -> u64 {
    std::env::var("SPARROW_SIM_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

fn boost_cfg(seed: u64, scenario: Scenario) -> SimConfig {
    SimConfig {
        workers: 5,
        seed,
        scenario,
        horizon: ms(1500),
        ..SimConfig::default()
    }
}

fn run_boost(cfg: &SimConfig) -> SimReport<BoostPayload> {
    // the canonical (run seed, worker, incarnation) derivation, shared
    // with `sparrow sim`, so restarts are deterministic too
    run_scenario(cfg, |id, incarnation| BoostSimWorker::for_run(cfg.seed, id, incarnation))
}

fn run_sgd(cfg: &SimConfig) -> SimReport<SgdPayload> {
    let (shards, valid) = sgd_sim_fixture(cfg.seed, cfg.workers);
    run_scenario(cfg, |id, _incarnation| {
        // a restarted machine re-reads the same on-disk shard but starts
        // from zero weights — SgdSimWorker::new is already that state
        SgdSimWorker::new(id, Arc::clone(&shards[id]), Arc::clone(&valid))
    })
}

fn assert_clean<P: Payload>(r: &SimReport<P>) {
    assert!(
        r.violations.is_empty(),
        "TMSN invariant violations:\n{}",
        r.violations.join("\n")
    );
}

/// Like [`assert_clean`], but first dumps the deterministic trace to
/// `target/sim_failures/<name>_seed<seed>.trace` so CI can upload the
/// exact failing repro as an artifact (`.github/workflows/ci.yml`).
fn assert_clean_dumping<P: Payload>(name: &str, seed: u64, r: &SimReport<P>) {
    if r.violations.is_empty() {
        return;
    }
    let dir = std::path::Path::new("target").join("sim_failures");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("{name}_seed{seed}.trace"));
    let _ = std::fs::write(
        &path,
        format!(
            "violations:\n{}\n\ntrace:\n{}",
            r.violations.join("\n"),
            r.trace
        ),
    );
    panic!(
        "TMSN invariant violations in '{name}' (seed {seed}; trace dumped to {}):\n{}",
        path.display(),
        r.violations.join("\n")
    );
}

/// The extended wire-accounting identity every run must satisfy: each
/// offered message is delivered, dropped, partition-blocked, discarded at
/// a dead node, or (fanout mode) deduped; duplicates add deliveries.
fn assert_wire_identity(s: &sparrow::sim::SimNetStats) {
    assert_eq!(
        s.delivered + s.to_down + s.deduped,
        s.offered - s.dropped - s.partition_blocked + s.duplicated,
        "{s:?}"
    );
}

// ---------------------------------------------------------------------------
// determinism: the acceptance criterion (byte-identical traces per seed)
// ---------------------------------------------------------------------------

#[test]
fn fixed_seed_gives_byte_identical_traces_for_every_preset() {
    let seed = env_seed();
    for name in PRESETS {
        let scenario = preset(name, 5).expect(name);
        let a = run_boost(&boost_cfg(seed, scenario.clone()));
        let b = run_boost(&boost_cfg(seed, scenario));
        assert_clean(&a);
        assert!(!a.trace.is_empty());
        assert_eq!(
            a.trace, b.trace,
            "trace of preset '{name}' is not a pure function of seed {seed}"
        );
        // the virtual timeline and counters replay exactly too
        assert_eq!(a.virtual_elapsed, b.virtual_elapsed);
        assert_eq!(a.net, b.net);
    }
}

#[test]
fn different_seeds_give_different_traces() {
    let scenario = preset("crash", 5).unwrap();
    let a = run_boost(&boost_cfg(1, scenario.clone()));
    let b = run_boost(&boost_cfg(2, scenario));
    assert_ne!(a.trace, b.trace, "the seed must actually steer the run");
}

#[test]
fn sgd_trace_is_byte_identical_per_seed() {
    let cfg = SimConfig {
        workers: 4,
        seed: env_seed(),
        scenario: preset("churn", 4).unwrap(),
        horizon: ms(1500),
        ..SimConfig::default()
    };
    let a = run_sgd(&cfg);
    let b = run_sgd(&cfg);
    assert_clean(&a);
    assert_eq!(a.trace, b.trace, "SGD trace is not a pure function of the seed");
}

// ---------------------------------------------------------------------------
// crash resilience: convergence despite k-of-n failures
// ---------------------------------------------------------------------------

#[test]
fn boosting_converges_despite_k_of_n_crashes() {
    let r = run_boost(&boost_cfg(env_seed(), preset("crash", 5).unwrap()));
    assert_clean(&r);
    let crashed: Vec<usize> =
        r.workers.iter().filter(|w| !w.alive).map(|w| w.id).collect();
    assert_eq!(crashed, vec![3, 4], "the crash preset fells the top 2 of 5");
    // survivors made certified progress and all ended on the best bound
    assert!(r.best.cert.loss_bound < 0.5, "bound {}", r.best.cert.loss_bound);
    assert!(r.survivors_converged(), "survivors diverged: {:?}", r.workers);
    // crashed workers stopped working (strictly fewer steps than peers)
    for &c in &crashed {
        assert!(r.workers[c].steps < r.workers[0].steps);
    }
    // the metrics pipeline saw the crashes, on the virtual clock
    let crash_events: Vec<_> =
        r.events.iter().filter(|e| e.kind == EventKind::Crash).collect();
    assert_eq!(crash_events.len(), 2);
    assert!(crash_events.iter().all(|e| e.elapsed >= ms(300)));
}

#[test]
fn restart_rejoins_with_nothing_but_broadcasts() {
    // churn preset: worker 1 crashes at 300ms and restarts at 900ms with
    // an empty model; by quiescence it must hold the best certificate —
    // the paper's "no recovery ceremony" claim.
    let r = run_boost(&boost_cfg(env_seed(), preset("churn", 5).unwrap()));
    assert_clean(&r);
    assert_eq!(r.workers[1].restarts, 1);
    assert!(r.workers[1].alive);
    assert!(!r.workers[4].alive, "churn crashes the last worker for good");
    assert!(r.survivors_converged(), "{:?}", r.workers);
    assert!(r.trace.contains("w1   restart"));
}

// ---------------------------------------------------------------------------
// laggards: the no-barrier claim
// ---------------------------------------------------------------------------

#[test]
fn laggard_never_blocks_peers() {
    let seed = env_seed();
    let base = run_boost(&boost_cfg(seed, preset("calm", 5).unwrap()));
    let lag = run_boost(&boost_cfg(seed, preset("laggard", 5).unwrap()));
    assert_clean(&base);
    assert_clean(&lag);
    // worker 1 is 8x slower from t=100ms; every other worker's work
    // schedule is *bit-identical* to the fault-free run — there is no
    // barrier anywhere for a slow machine to hold
    for id in [0usize, 2, 3, 4] {
        assert_eq!(
            base.workers[id].steps, lag.workers[id].steps,
            "laggard changed peer {id}'s step count"
        );
        assert_eq!(
            base.workers[id].published, lag.workers[id].published,
            "laggard changed peer {id}'s publish count"
        );
    }
    // the laggard itself does proportionally less
    assert!(lag.workers[1].steps < base.workers[1].steps / 3);
    // and still converges with everyone else
    assert!(lag.survivors_converged());
}

// ---------------------------------------------------------------------------
// partitions
// ---------------------------------------------------------------------------

#[test]
fn partition_heals_and_cluster_reconverges() {
    let r = run_boost(&boost_cfg(env_seed(), preset("partition", 5).unwrap()));
    assert_clean(&r);
    assert!(r.net.partition_blocked > 0, "partition never blocked anything");
    assert!(r.survivors_converged(), "cluster did not reconverge after heal");
    assert!(r.trace.contains("net  partition"));
    assert!(r.trace.contains("net  heal"));
}

#[test]
fn unhealed_partition_converges_per_island() {
    // without a heal, each island must still satisfy every invariant and
    // converge internally (global convergence is impossible by design)
    let scenario = Scenario::new().at(
        ms(100),
        ScenarioEvent::Partition(vec![vec![0, 1], vec![2, 3, 4]]),
    );
    let r = run_boost(&boost_cfg(env_seed(), scenario));
    assert_clean(&r);
    for island in [vec![0usize, 1], vec![2usize, 3, 4]] {
        let best = island
            .iter()
            .map(|&i| r.workers[i].final_summary)
            .fold(f64::INFINITY, f64::min);
        for &i in &island {
            assert_eq!(
                r.workers[i].final_summary, best,
                "island {island:?} did not converge internally"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// chaotic wire: drop + duplication + reordering
// ---------------------------------------------------------------------------

#[test]
fn lossy_duplicating_reordering_links_preserve_all_invariants() {
    let cfg = SimConfig {
        workers: 5,
        seed: env_seed() ^ 0xC405,
        net: SimNetConfig {
            edge: EdgeFaults::lossy(0.25, 0.25, 0.5),
            ..SimNetConfig::default()
        },
        scenario: preset("churn", 5).unwrap(),
        horizon: ms(1500),
        ..SimConfig::default()
    };
    let r = run_boost(&cfg);
    // the whole point: duplicated/reordered/stale deliveries are rejected
    // by the verdict rule, never adopted — zero invariant violations
    assert_clean(&r);
    let s = &r.net;
    assert!(s.dropped > 0 && s.duplicated > 0 && s.reordered > 0, "{s:?}");
    // wire accounting: every offered message is delivered, dropped,
    // blocked, discarded at a dead node, or (fanout only) deduped;
    // duplicates add deliveries
    assert_eq!(
        s.delivered + s.to_down + s.deduped,
        s.offered - s.dropped - s.partition_blocked + s.duplicated,
        "{s:?}"
    );
    // duplicates of an adopted payload must show up as rejects
    assert!(r.workers.iter().map(|w| w.rejects).sum::<u64>() > 0);
}

// ---------------------------------------------------------------------------
// SGD workload: the same engine carries a second learner
// ---------------------------------------------------------------------------

#[test]
fn sgd_converges_despite_crashes() {
    // crash-only scenario: every survivor sees every broadcast, so exact
    // convergence to the best certificate is structurally guaranteed —
    // even if the best publisher is one of the machines that later dies
    let cfg = SimConfig {
        workers: 4,
        seed: env_seed(),
        scenario: preset("crash", 4).unwrap(),
        horizon: ms(1500),
        ..SimConfig::default()
    };
    let r = run_sgd(&cfg);
    assert_clean(&r);
    assert!(
        r.best.cert.loss < std::f64::consts::LN_2,
        "certified loss {} not below the zero model",
        r.best.cert.loss
    );
    assert_eq!(r.workers.iter().filter(|w| !w.alive).count(), 2);
    assert!(r.survivors_converged(), "{:?}", r.workers);
    // someone adopted someone else's model (the protocol did its job)
    assert!(r.workers.iter().map(|w| w.accepts).sum::<u64>() > 0);
}

#[test]
fn sgd_survives_churn_and_restart_recovers() {
    let cfg = SimConfig {
        workers: 4,
        seed: env_seed(),
        scenario: preset("churn", 4).unwrap(),
        horizon: ms(1500),
        ..SimConfig::default()
    };
    let r = run_sgd(&cfg);
    assert_clean(&r);
    assert!(r.best.cert.loss < std::f64::consts::LN_2);
    // the restarted worker rebuilt from zero weights (plus any broadcasts
    // it heard) and must itself end with a certified sub-ln2 model; note
    // TMSN promises *progress*, not late-joiner state sync — if every
    // peer plateaued under the ε gap before the restart, nothing obliges
    // them to re-broadcast, so exact equality is not asserted here
    // (see sgd_converges_despite_crashes for the exact-convergence case)
    let w1 = &r.workers[1];
    assert_eq!((w1.restarts, w1.alive), (1, true));
    assert!(
        w1.final_summary < std::f64::consts::LN_2,
        "restarted worker never recovered: {w1:?}"
    );
    assert!(r.workers.iter().map(|w| w.accepts).sum::<u64>() > 0);
    assert!(r.trace.contains("w1   restart"));
}

// ---------------------------------------------------------------------------
// the production Driver runs unmodified over SimNet + SimClock
// ---------------------------------------------------------------------------

#[test]
fn driver_runs_unmodified_over_simnet_under_virtual_time() {
    let clock = Arc::new(SimClock::new());
    let (log, rx) = EventLog::with_clock(clock.clone());
    let delay = EdgeFaults {
        delay_min: ms(5),
        delay_max: ms(5),
        ..EdgeFaults::default()
    };
    let cfg = SimNetConfig {
        edge: delay,
        ..SimNetConfig::default()
    };
    let (net, mut eps) = SimNet::<BoostPayload>::new(2, cfg, sparrow::util::rng::Rng::new(3));
    let b_ep = eps.pop().unwrap();
    let a_ep = eps.pop().unwrap();
    let mut a = Driver::new(Tmsn::<BoostPayload>::new(0), a_ep, log.clone());
    let mut b = Driver::new(Tmsn::<BoostPayload>::new(1), b_ep, log);

    // a real local improvement through the production send path
    let mut model = a.payload().model.clone();
    model.push(sparrow::model::Stump::new(0, 0.0, 1.0), 0.2);
    let improved = a.payload().improved(model, 0.1);
    a.publish(improved);

    // nothing arrives until virtual time reaches the link delay
    assert_eq!(b.poll_adopt(&mut |_, _| {}), 0);
    assert_eq!(net.next_due(), Some(ms(5)));
    clock.advance_to(ms(5));
    net.deliver_due(ms(5));
    assert_eq!(b.poll_adopt(&mut |_, _| {}), 1, "driver must adopt over SimNet");
    assert_eq!(b.cert().origin, 0);
    assert!(b.cert().loss_bound < 1.0);

    // the unmodified metrics pipeline stamped *virtual* time
    let events = sparrow::metrics::drain(&rx);
    let accept = events
        .iter()
        .find(|e| e.kind == EventKind::Accept)
        .expect("accept event");
    assert_eq!(accept.elapsed, ms(5), "accept must be stamped at virtual t=5ms");
}

// ---------------------------------------------------------------------------
// the full battery on the CI seed matrix
// ---------------------------------------------------------------------------

#[test]
fn seeded_battery_all_presets_hold_all_invariants() {
    let seed = env_seed();
    for name in PRESETS {
        let r = run_boost(&boost_cfg(seed, preset(name, 5).expect(name)));
        assert_clean_dumping(name, seed, &r);
        assert!(
            r.best.cert().summary() < 1.0,
            "preset '{name}' made no certified progress"
        );
        assert!(
            r.survivors_converged(),
            "preset '{name}' (seed {seed}) did not converge: {:?}",
            r.workers
        );
    }
}

// ---------------------------------------------------------------------------
// elastic swarm: dynamic membership (join) and crash-rejoin from checkpoint
// ---------------------------------------------------------------------------

#[test]
fn joiners_are_discovered_and_converge_with_the_founders() {
    let seed = env_seed();
    let r = run_boost(&boost_cfg(seed, preset("join", 5).unwrap()));
    assert_clean_dumping("join", seed, &r);
    // two workers joined the 5 founders mid-run
    assert_eq!(r.workers.len(), 7);
    assert!(r.trace.contains("w5   join"));
    assert!(r.trace.contains("w6   join"));
    // the joiners did real work and ended on the swarm's best certificate
    assert!(r.workers[5].steps > 0 && r.workers[6].steps > 0);
    assert!(r.survivors_converged(), "{:?}", r.workers);
    let join_events: Vec<_> =
        r.events.iter().filter(|e| e.kind == EventKind::Join).collect();
    assert_eq!(join_events.len(), 2);
}

#[test]
fn adoption_is_strictly_better_regardless_of_join_order() {
    // the same swarm built in two different join orders (joins early vs
    // late) must end converged with zero invariant violations both ways —
    // accept-iff-strictly-better does not depend on membership history
    let seed = env_seed();
    for join_at in [ms(50), ms(700)] {
        let scenario = Scenario::new()
            .at(join_at, ScenarioEvent::Join(5))
            .at(join_at + ms(30), ScenarioEvent::Join(6));
        let r = run_boost(&boost_cfg(seed, scenario));
        assert_clean_dumping("join_order", seed, &r);
        assert_eq!(r.workers.len(), 7);
        assert!(r.survivors_converged(), "join_at={join_at:?}: {:?}", r.workers);
    }
}

#[test]
fn rejoin_resumes_from_checkpoint_not_scratch() {
    let seed = env_seed();
    let r = run_boost(&boost_cfg(seed, preset("churn", 5).unwrap()));
    assert_clean_dumping("churn_rejoin", seed, &r);
    // the restarted worker resumed from its last committed payload: the
    // resume trace line carries a finite certificate, not the initial one
    assert!(r.trace.contains("w1   resume  cert="), "{}", r.trace);
    assert!(!r.trace.contains("cert=inf"), "restart lost its checkpoint");
    let rejoin: Vec<_> =
        r.events.iter().filter(|e| e.kind == EventKind::Rejoin).collect();
    assert_eq!(rejoin.len(), 1);
    assert_eq!(rejoin[0].worker, 1);
}

// ---------------------------------------------------------------------------
// one-way (asymmetric) partitions
// ---------------------------------------------------------------------------

#[test]
fn one_way_partition_blocks_exactly_the_forward_direction() {
    let seed = env_seed();
    // worker 0 can hear everyone but nobody hears worker 0
    let scenario = Scenario::new()
        .at(
            ms(100),
            ScenarioEvent::PartitionOneWay(vec![(0, 1), (0, 2), (0, 3), (0, 4)]),
        )
        .at(ms(800), ScenarioEvent::Heal);
    let r = run_boost(&boost_cfg(seed, scenario));
    assert_clean_dumping("oneway", seed, &r);
    assert!(r.net.partition_blocked > 0, "{:?}", r.net);
    assert_wire_identity(&r.net);
    assert!(r.trace.contains("partition-oneway"));
    // after the heal everyone reconverges
    assert!(r.survivors_converged(), "{:?}", r.workers);
}

#[test]
fn prop_asymmetric_partitions_preserve_wire_accounting() {
    // seeded sweep over random asymmetric edge sets: whatever direction
    // mix is blocked, the wire identity and every TMSN invariant hold
    let base = env_seed();
    for i in 0..8u64 {
        let mut rng = sparrow::util::rng::Rng::new(base ^ (0xA11CE + i));
        let mut edges = Vec::new();
        for a in 0..5usize {
            for b in 0..5usize {
                if a != b && rng.bernoulli(0.3) {
                    edges.push((a, b));
                }
            }
        }
        if edges.is_empty() {
            edges.push((0, 1));
        }
        let scenario = Scenario::new()
            .at(ms(100), ScenarioEvent::PartitionOneWay(edges.clone()))
            .at(ms(900), ScenarioEvent::Heal);
        let r = run_boost(&boost_cfg(base ^ i, scenario));
        assert_clean_dumping("oneway_prop", base ^ i, &r);
        assert_wire_identity(&r.net);
        assert!(
            r.survivors_converged(),
            "edges {edges:?} (seed {}) did not reconverge after heal",
            base ^ i
        );
    }
}

// ---------------------------------------------------------------------------
// gossip fanout: O(n·K·TTL) dissemination, equivalent in final-model terms
// ---------------------------------------------------------------------------

#[test]
fn fanout_reaches_the_same_final_model_as_full_broadcast_on_every_preset() {
    // independent certificate streams (DESIGN.md §12) make each worker's
    // candidate sequence a pure function of its own RNG, so the best
    // certified bound is *bitwise* mode-invariant: the globally minimal
    // own-bound gets published under any delivery order
    let seed = env_seed();
    for name in PRESETS {
        let scenario = preset(name, 5).expect(name);
        let mk = |mode: BroadcastMode| SimConfig {
            workers: 5,
            seed,
            scenario: scenario.clone(),
            horizon: ms(1500),
            net: SimNetConfig {
                mode,
                ..SimNetConfig::default()
            },
            ..SimConfig::default()
        };
        let spawn = |id: usize, inc: u64| BoostSimWorker::independent_for_run(seed, id, inc);
        let full = run_scenario(&mk(BroadcastMode::Full), spawn);
        let fan = run_scenario(&mk(BroadcastMode::Fanout { k: 3, ttl: 16 }), spawn);
        assert_clean_dumping(&format!("{name}_full"), seed, &full);
        assert_clean_dumping(&format!("{name}_fanout"), seed, &fan);
        assert_eq!(
            full.best.cert.loss_bound.to_bits(),
            fan.best.cert.loss_bound.to_bits(),
            "preset '{name}' (seed {seed}): fanout best {} != full best {}",
            fan.best.cert.loss_bound,
            full.best.cert.loss_bound,
        );
        assert!(fan.net.forwarded > 0, "preset '{name}' gossip never relayed");
        assert_wire_identity(&fan.net);
        assert!(fan.survivors_converged(), "preset '{name}': {:?}", fan.workers);
    }
}

#[test]
fn fanout_origin_cost_is_k_not_cluster_size() {
    // the wire-cost claim of DESIGN.md §12: full mode pays n-1 offers at
    // the *origin* of every publish, fanout pays at most K and shifts
    // dissemination onto TTL-bounded relays — O(n·K) total per flooded
    // payload, never O(n) at one node
    let seed = env_seed();
    let mk = |mode: BroadcastMode| SimConfig {
        workers: 12,
        seed,
        scenario: Scenario::new(),
        horizon: ms(600),
        net: SimNetConfig {
            mode,
            ..SimNetConfig::default()
        },
        ..SimConfig::default()
    };
    let spawn = |id: usize, inc: u64| BoostSimWorker::independent_for_run(seed, id, inc);
    let full = run_scenario(&mk(BroadcastMode::Full), spawn);
    let fan = run_scenario(&mk(BroadcastMode::Fanout { k: 2, ttl: 24 }), spawn);
    assert_clean(&full);
    assert_clean(&fan);
    // full: exactly n-1 per publish, and nothing is ever relayed
    assert_eq!(full.net.offered, full.net.broadcasts * 11);
    assert_eq!(full.net.forwarded, 0);
    // fanout: origin offers (offered minus relay offers) are capped at K
    // per publish; dissemination happens via relays instead
    let origin_offers = fan.net.offered - fan.net.forwarded;
    assert!(
        origin_offers <= fan.net.broadcasts * 2,
        "origin cost exceeded K: {origin_offers} offers for {} publishes",
        fan.net.broadcasts
    );
    assert!(fan.net.forwarded > 0, "gossip never relayed");
    assert_wire_identity(&fan.net);
    // and the cheaper wire still lands on the bit-identical best model
    assert_eq!(
        full.best.cert.loss_bound.to_bits(),
        fan.best.cert.loss_bound.to_bits()
    );
}

// ---------------------------------------------------------------------------
// churn_large: the 100..1000-virtual-worker elastic swarm battery
// ---------------------------------------------------------------------------

/// Swarm size for the large battery; CI sweeps `SPARROW_SIM_WORKERS`.
fn churn_workers() -> usize {
    std::env::var("SPARROW_SIM_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100)
}

fn churn_large_cfg(seed: u64, n: usize, mode: BroadcastMode, horizon: Duration) -> SimConfig {
    SimConfig {
        workers: n,
        seed,
        scenario: preset("churn_large", n).expect("churn_large"),
        horizon,
        net: SimNetConfig {
            mode,
            // per-message wire tracing is O(messages) string work — the
            // counters and worker lines keep the trace deterministic
            wire_trace: false,
            ..SimNetConfig::default()
        },
        ..SimConfig::default()
    }
}

#[test]
fn churn_large_battery_holds_invariants_and_replays_byte_identically() {
    let seed = env_seed();
    let n = churn_workers();
    let cfg = churn_large_cfg(seed, n, BroadcastMode::Full, ms(1500));
    let expected = cfg.scenario.validate(n).expect("valid preset");
    let a = run_boost(&cfg);
    assert_clean_dumping("churn_large", seed, &a);
    assert_eq!(a.workers.len(), expected, "joins all landed");
    let alive = a.workers.iter().filter(|w| w.alive).count();
    assert!(
        alive * 2 >= a.workers.len(),
        "churn felled too many: {alive}/{}",
        a.workers.len()
    );
    assert!(a.survivors_converged(), "swarm did not converge");
    // the preset restarts every 2nd crash victim, so any swarm big enough
    // for >= 2 crashes must show a checkpoint rejoin
    if n >= 8 {
        assert!(a.workers.iter().any(|w| w.restarts > 0), "nobody rejoined");
    }
    assert_wire_identity(&a.net);
    // byte-identical replay at 100+ workers
    let b = run_boost(&cfg);
    assert_eq!(a.trace, b.trace, "churn_large trace not a pure function of seed {seed}");
    assert_eq!(a.net, b.net);
}

#[test]
fn churn_large_fanout_agrees_with_full_broadcast() {
    let seed = env_seed();
    let n = churn_workers();
    let spawn = |id: usize, inc: u64| BoostSimWorker::independent_for_run(seed, id, inc);
    let full = run_scenario(&churn_large_cfg(seed, n, BroadcastMode::Full, ms(800)), spawn);
    let fan = run_scenario(
        &churn_large_cfg(seed, n, BroadcastMode::Fanout { k: 3, ttl: 0 }, ms(800)),
        spawn,
    );
    assert_clean_dumping("churn_large_full", seed, &full);
    assert_clean_dumping("churn_large_fanout", seed, &fan);
    assert_eq!(
        full.best.cert.loss_bound.to_bits(),
        fan.best.cert.loss_bound.to_bits(),
        "fanout best {} != full best {} at n={n}",
        fan.best.cert.loss_bound,
        full.best.cert.loss_bound,
    );
    assert!(fan.net.forwarded > 0);
    if n >= 20 {
        assert!(fan.net.deduped > 0, "at n={n} gossip must hit duplicates");
    }
    assert_wire_identity(&fan.net);
}

#[test]
#[ignore = "1000-virtual-worker stress battery; run with: cargo test --test sim_cluster -- --ignored"]
fn churn_large_scales_to_a_thousand_workers() {
    let seed = env_seed();
    let n = 1000;
    let spawn = |id: usize, inc: u64| BoostSimWorker::independent_for_run(seed, id, inc);
    // the horizon must outlive the preset's final heal (t=1000ms) so
    // post-heal publishes can flood and convergence is assertable
    let cfg = churn_large_cfg(seed, n, BroadcastMode::Fanout { k: 3, ttl: 0 }, ms(1100));
    let expected = cfg.scenario.validate(n).expect("valid preset");
    let r = run_scenario(&cfg, spawn);
    assert_clean_dumping("churn_large_1000", seed, &r);
    assert_eq!(r.workers.len(), expected);
    let alive = r.workers.iter().filter(|w| w.alive).count();
    assert!(alive * 2 >= r.workers.len());
    assert!(r.survivors_converged(), "1000-worker swarm did not converge");
    assert_wire_identity(&r.net);
}
