//! End-to-end tests for the background sampler pipeline (DESIGN.md §4):
//! real multi-threaded cluster runs with `SamplerMode::Background`,
//! checking the swap/invalidation event grammar and that the default
//! blocking mode is untouched by the knob.

mod common;

use std::time::Duration;

use sparrow::config::{SamplerMode, TrainConfig};
use sparrow::coordinator::{train_cluster, ClusterOutcome};
use sparrow::metrics::EventKind;
use sparrow::scanner::NativeBackend;

fn run(patch: impl FnOnce(&mut TrainConfig)) -> ClusterOutcome {
    let (path, test) = common::synth_store("sparrow_pipeline_int", 123, 20_000, 2_000);
    let mut cfg = TrainConfig {
        num_workers: 2,
        sample_size: 2048,
        max_rules: 10,
        time_limit: Duration::from_secs(30),
        gamma0: 0.2,
        sampler_mode: SamplerMode::Background,
        ..TrainConfig::default()
    };
    patch(&mut cfg);
    train_cluster(&cfg, &path, &test, "pipeline", &|_| {
        Ok(Box::new(NativeBackend))
    })
    .unwrap()
}

#[test]
fn background_mode_learns() {
    let out = run(|_| {});
    assert!(!out.model.is_empty(), "no rules learned in background mode");
    // every sample that reached a scanner arrived through the swap path
    let swaps = out
        .events
        .iter()
        .filter(|e| e.kind == EventKind::SampleSwap)
        .count();
    assert!(swaps >= 2, "each worker must install at least one sample");
    for w in &out.workers {
        assert!(w.resamples >= 1, "worker {} never installed a sample", w.id);
        assert!(!w.crashed, "worker {} crashed", w.id);
    }
}

#[test]
fn builder_events_balance() {
    // builder-side grammar: every build that starts either completes
    // (ResampleEnd) or is invalidated (BuildAbort) — per worker lane
    let out = run(|c| c.num_workers = 4);
    for w in 0..4 {
        let count = |k: EventKind| {
            out.events
                .iter()
                .filter(|e| e.worker == w && e.kind == k)
                .count()
        };
        let starts = count(EventKind::ResampleStart);
        let ends = count(EventKind::ResampleEnd);
        let aborts = count(EventKind::BuildAbort);
        assert!(starts >= 1, "worker {w} never started a build");
        // the last build may still be in flight when the run stops, so
        // starts can exceed ends+aborts by at most one
        assert!(
            starts == ends + aborts || starts == ends + aborts + 1,
            "worker {w}: starts={starts} ends={ends} aborts={aborts}"
        );
        // a worker can only swap in samples that finished building
        let swaps = count(EventKind::SampleSwap);
        assert!(swaps <= ends, "worker {w}: swaps={swaps} > ends={ends}");
    }
}

#[test]
fn background_cluster_still_certifies_and_adopts() {
    // protocol invariants don't care how the sample is produced: bounds
    // stay monotone per worker and adoptions still happen
    let out = run(|c| {
        c.num_workers = 4;
        c.max_rules = 12;
    });
    let mut bound = vec![f64::INFINITY; 4];
    for e in &out.events {
        if matches!(e.kind, EventKind::LocalImprovement | EventKind::Accept) {
            assert!(
                e.value <= bound[e.worker] + 1e-9,
                "worker {} bound went up",
                e.worker
            );
            bound[e.worker] = e.value;
        }
    }
    let accepts = out
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Accept)
        .count();
    assert!(accepts > 0, "4-worker background run had no adoptions");
}

#[test]
fn blocking_mode_never_emits_pipeline_events() {
    // the knob must gate the pipeline completely: a default (blocking)
    // run contains no swap or abort events anywhere
    let out = run(|c| c.sampler_mode = SamplerMode::Blocking);
    assert!(!out.model.is_empty());
    assert!(out
        .events
        .iter()
        .all(|e| e.kind != EventKind::SampleSwap && e.kind != EventKind::BuildAbort));
}
