#!/usr/bin/env bash
# End-to-end control-plane demo (`make serve-demo`, OPERATIONS.md §1):
# synthesize a small store, start `sparrow serve`, round-trip the admin
# and serve endpoints through `sparrow rpc`, then shut the worker down
# cleanly and check it wrote its model. Override the port pair with
# SERVE_DEMO_PORT=N (uses N and N+1).
set -euo pipefail
cd "$(dirname "$0")/.."

PORT=${SERVE_DEMO_PORT:-7790}
SERVE_ADDR="127.0.0.1:${PORT}"
ADMIN_ADDR="127.0.0.1:$((PORT + 1))"

(cd rust && cargo build --release)
BIN=rust/target/release/sparrow

TMP=$(mktemp -d)
SERVE_PID=""
cleanup() {
  [ -n "$SERVE_PID" ] && kill "$SERVE_PID" 2>/dev/null || true
  rm -rf "$TMP"
}
trap cleanup EXIT

"$BIN" gen-data --out "$TMP/train.sprw" --train-n 4000 --features 16 --data-seed 5

"$BIN" serve --data "$TMP/train.sprw" --workers 1 --max-rules 8 \
    --time-limit 30 --serve-addr "$SERVE_ADDR" --admin-addr "$ADMIN_ADDR" \
    --out "$TMP/model.txt" &
SERVE_PID=$!

# both endpoints bind before training starts; poll until the admin
# endpoint answers (the rpc client itself retries connects for ~1s)
for _ in $(seq 1 60); do
  if "$BIN" rpc --addr "$ADMIN_ADDR" --method ping >/dev/null 2>&1; then
    break
  fi
  sleep 0.25
done

echo "--- admin ping"
"$BIN" rpc --addr "$ADMIN_ADDR" --method ping
echo "--- predict (16-feature row; served model hot-swaps as training adopts)"
ROW=$(printf '0.5,%.0s' {1..15}; printf '0.5')
"$BIN" rpc --addr "$SERVE_ADDR" --method predict --params "{\"row\":[${ROW}]}"
echo "--- metrics.snapshot"
"$BIN" rpc --addr "$ADMIN_ADDR" --method metrics.snapshot
echo "--- serve.stats"
"$BIN" rpc --addr "$SERVE_ADDR" --method serve.stats
echo "--- shutdown"
"$BIN" rpc --addr "$ADMIN_ADDR" --method shutdown

wait "$SERVE_PID"
SERVE_PID=""
test -f "$TMP/model.txt" || { echo "serve demo FAILED: no model written" >&2; exit 1; }
echo "serve-demo OK"
