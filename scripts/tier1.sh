#!/usr/bin/env bash
# Tier-1 verification gate (see ROADMAP.md): release build + full test suite.
#
# Single entry point shared by CI (.github/workflows/ci.yml) and local devs:
#
#     ./scripts/tier1.sh                   # default build
#     ./scripts/tier1.sh --features simd   # lane-kernel build (CI matrix leg)
#
# Extra arguments are passed through to every cargo build/test invocation
# of the sparrow package, so the whole gate runs under the same feature
# set. Keep this file in sync with the "Tier-1 verify" line in ROADMAP.md.
set -euo pipefail

cd "$(dirname "$0")/../rust"

cargo build --release "$@"
# Examples and harness=false benches are the first casualties of an API
# redesign and `cargo test` does not build the benches — gate them too.
cargo build --examples --benches "$@"
cargo test -q "$@"

# The workspace root package is `sparrow`, so the gate above does not reach
# the vendored shim crates; test them explicitly (fast — a handful of tests).
cargo test -q -p anyhow -p xla
