#!/usr/bin/env bash
# OPERATIONS.md coverage gate: every RPC method in admin/proto.rs
# (ADMIN_METHODS + SERVE_METHODS) and every event wire name in
# metrics/events.rs must be documented in OPERATIONS.md as `name`.
# Pure text diff — needs no Rust toolchain, so it runs anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

DOC=OPERATIONS.md
PROTO=rust/src/admin/proto.rs
EVENTS=rust/src/metrics/events.rs
fail=0

# ---- RPC methods: the quoted strings inside the two const lists ------
# ADMIN_METHODS is a multi-line list; SERVE_METHODS is single-line.
methods=$(
  awk '/^pub const (ADMIN|SERVE)_METHODS/,/\];|\];$/' "$PROTO" \
    | grep -o '"[a-z_.]*"' | tr -d '"' | sort -u
)
[ -n "$methods" ] || { echo "error: extracted no methods from $PROTO" >&2; exit 2; }

for m in $methods; do
  if ! grep -qF "\`$m\`" "$DOC"; then
    echo "MISSING: RPC method \`$m\` (from $PROTO) is not documented in $DOC" >&2
    fail=1
  fi
done

# ---- Event kinds: the wire names returned by EventKind::as_str -------
events=$(
  awk '/pub fn as_str/,/^    }/' "$EVENTS" \
    | grep -o '"[a-z_]*"' | tr -d '"' | sort -u
)
[ -n "$events" ] || { echo "error: extracted no event names from $EVENTS" >&2; exit 2; }

for e in $events; do
  if ! grep -qF "\`$e\`" "$DOC"; then
    echo "MISSING: event kind \`$e\` (from $EVENTS) is not documented in $DOC" >&2
    fail=1
  fi
done

if [ "$fail" -ne 0 ]; then
  echo "ops-doc check FAILED: update OPERATIONS.md (see above)" >&2
  exit 1
fi
echo "ops-doc check OK: $(echo "$methods" | wc -w | tr -d ' ') methods, $(echo "$events" | wc -w | tr -d ' ') event kinds all documented"
