# Convenience entry points. The authoritative verification gate is
# scripts/tier1.sh (used verbatim by CI).

.PHONY: tier1 build test fmt clippy doc check-ops-doc serve-demo artifacts bench bench-scan bench-ooc bench-resilience sim chaos clean

tier1:
	./scripts/tier1.sh

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

fmt:
	cd rust && cargo fmt

clippy:
	cd rust && cargo clippy --all-targets -- -D warnings

# API docs for the sparrow crate only (vendored shims excluded); rustdoc
# warnings surface missing_docs from the modules that opt in (sampler/,
# sampling/, data/store.rs, data/strata.rs).
doc:
	cd rust && cargo doc --no-deps

# OPERATIONS.md coverage gate (CI `doc` job): every RPC method and event
# kind in the source must be documented in the operator's manual.
check-ops-doc:
	./scripts/check_ops_doc.sh

# Scripted control-plane round trip (OPERATIONS.md §1): gen-data →
# `sparrow serve` → ping / predict / metrics.snapshot / serve.stats →
# shutdown, all through `sparrow rpc`.
serve-demo:
	./scripts/serve_demo.sh

# Deterministic fault-injection scenario suite (DESIGN.md §9). Pick the
# seed with SPARROW_SIM_SEED=N; CI sweeps seeds 1-3 in the `sim` job.
sim:
	cd rust && cargo test --test sim_cluster

# Chaos-proxy battery against the real TCP fabric (DESIGN.md §13). Pick
# the seed with SPARROW_CHAOS_SEED=N; CI sweeps seeds 1-3 in the `chaos`
# job and uploads frame-trace artifacts on failure.
chaos:
	cd rust && cargo test --release --test cluster_integration --test robustness

# Scan-engine sweep (DESIGN.md §8/§14): rows vs binned, scalar vs lane
# kernels, × threads, plus the threaded suffix fold → BENCH_scan.json at
# the repo root, tracking the scan-throughput trajectory across PRs. The
# bench asserts rows == binned-scalar == binned-simd bit-identity before
# timing. Built with --features simd so the lane rows are populated; the
# scalar rows double as the default-build numbers (same machine code —
# the feature only *adds* kernels, §14).
bench-scan:
	cd rust && cargo bench --features simd --bench micro_hotpath -- --json ../BENCH_scan.json

# AOT-lower the L2/L1 Python graph to HLO-text artifacts consumed by the
# xla-* backends (requires a JAX environment; see python/compile/aot.py).
# rust/artifacts is where the runtime tests and benches look for them.
# The scan sweep runs first so BENCH_scan.json is refreshed even when no
# JAX environment is available for the HLO step.
artifacts: bench-scan bench-resilience
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts

# Out-of-core data plane (DESIGN.md §11): mem vs tiered build rate on a
# store ~4x the tiered memory budget, with a byte-identity assertion,
# → BENCH_ooc.json at the repo root.
bench-ooc:
	cd rust && cargo bench --bench ooc_scan -- --json ../BENCH_ooc.json

# Self-healing fabric latency contract + laggard sweep (DESIGN.md §13 /
# paper §4): broadcast push p50/p99 healthy vs blackholed, reconnect time,
# retained-progress table, → BENCH_resilience.json at the repo root.
bench-resilience:
	cd rust && cargo bench --bench resilience -- --json ../BENCH_resilience.json

bench:
	cd rust && cargo bench

clean:
	cd rust && cargo clean
