"""Pure-jnp oracles for the L1 kernel and the L2 scan-batch graph.

These are the correctness ground truth: every Pallas/fused implementation is
asserted allclose against these in ``python/tests/`` (and the Rust native
scanner replicates the same math, cross-checked in Rust integration tests).
"""

from __future__ import annotations

import jax.numpy as jnp


def stump_predictions(x: jnp.ndarray, grid_thr: jnp.ndarray) -> jnp.ndarray:
    """``(B, F, NT)`` predictions of every candidate stump on every example.

    ``h_{f,t}(x) = 2 * (x[f] > grid_thr[f, t]) - 1  in {-1, +1}``.
    """
    return (2.0 * (x[:, :, None] > grid_thr[None, :, :]) - 1.0).astype(x.dtype)


def edges(x: jnp.ndarray, u: jnp.ndarray, grid_thr: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the Pallas edge kernel: ``edges[f,t] = sum_i u_i h_{f,t}(x_i)``."""
    pred = stump_predictions(x, grid_thr)  # (B, F, NT)
    return jnp.einsum("b,bfn->fn", u.reshape(-1).astype(x.dtype), pred)


def strong_rule_scores(
    x: jnp.ndarray,
    feat_onehot: jnp.ndarray,
    thr: jnp.ndarray,
    sign: jnp.ndarray,
    alpha: jnp.ndarray,
) -> jnp.ndarray:
    """Oracle for the strong rule ``H(x) = sum_t alpha_t h_t(x)``.

    The model is padded to a fixed ``T``: unused slots carry ``alpha = 0``.
    ``feat_onehot[:, t]`` is the one-hot column of stump t's feature,
    ``thr[t]`` its threshold, ``sign[t]`` its polarity in {-1,+1}.
    """
    xsel = x @ feat_onehot  # (B, T) — selected feature values
    preds = sign[None, :] * (2.0 * (xsel > thr[None, :]) - 1.0)
    return preds @ alpha


def scan_batch(
    x: jnp.ndarray,
    y: jnp.ndarray,
    w_s: jnp.ndarray,
    score_s: jnp.ndarray,
    feat_onehot: jnp.ndarray,
    thr: jnp.ndarray,
    sign: jnp.ndarray,
    alpha: jnp.ndarray,
    grid_thr: jnp.ndarray,
):
    """Oracle for the full scan-batch computation (see model.scan_batch)."""
    scores = strong_rule_scores(x, feat_onehot, thr, sign, alpha)
    w = w_s * jnp.exp(-y * (scores - score_s))
    u = w * y
    e = edges(x, u, grid_thr)
    return scores, w, e, jnp.sum(w), jnp.sum(w * w)
