"""L1 — Pallas edge-accumulation kernel (the Sparrow compute hot-spot).

The Scanner's inner loop estimates, for every candidate decision stump
``h_{f,t}(x) = 2*(x[f] > thr[f,t]) - 1``, the weighted edge

    edges[f, t] = sum_i  u_i * h_{f,t}(x_i),      u_i = w_i * y_i

over a batch of examples.  This is the dominant cost of boosting-by-scanning
(paper §4.1: "the most time consuming part of our algorithms is the
computation of the predictions of the strong rules" and the per-candidate
edge updates).

Hardware adaptation (DESIGN.md §2): the paper ran on CPU clusters; here the
batch-of-examples x candidate-grid reduction is expressed as a tiled TPU
kernel:

  * grid = (F/Fb, B/Bb); the feature axis is parallel, the batch axis is a
    reduction that accumulates into a VMEM-resident ``(Fb, NT)`` output tile
    (the output BlockSpec ignores the batch grid axis, so Pallas keeps the
    tile in VMEM across the whole reduction).
  * each grid step streams one ``(Bb, Fb)`` tile of X from HBM into VMEM
    via its BlockSpec — the HBM<->VMEM schedule the paper's CPU code did
    with cache-friendly sequential scans.
  * ``u`` is broadcast across lanes; the compare+mask+accumulate maps onto
    the VPU; the companion strong-rule scoring in model.py is a one-hot
    matmul that maps onto the MXU.

The kernel MUST be lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).
Numerics are validated against the pure-jnp oracle in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes, chosen for TPU VMEM (DESIGN.md §7):
#   X tile  (256, 128) f32  = 128 KiB
#   scratch (128, NT=8) f32 =   4 KiB
#   compare tensor (256,128,8) f32 = 1 MiB intermediate
# comfortably under the ~16 MiB VMEM budget, and (8,128)-lane aligned.
DEFAULT_BLOCK_B = 256
DEFAULT_BLOCK_F = 128


def _edge_kernel(x_ref, u_ref, thr_ref, out_ref):
    """One grid step: accumulate the edge contribution of a (Bb, Fb) tile."""
    b_step = pl.program_id(1)

    x = x_ref[...]  # (Bb, Fb)
    u = u_ref[...]  # (Bb, 1)
    thr = thr_ref[...]  # (Fb, NT)

    # h_{f,t}(x_i) = 2*(x[i,f] > thr[f,t]) - 1  in {-1, +1}
    gt = (x[:, :, None] > thr[None, :, :]).astype(x.dtype)  # (Bb, Fb, NT)
    pred = 2.0 * gt - 1.0
    # contrib[f, t] = sum_i u[i] * pred[i, f, t]
    contrib = jnp.sum(u[:, :, None] * pred, axis=0)  # (Fb, NT)

    @pl.when(b_step == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(b_step > 0)
    def _accumulate():
        out_ref[...] += contrib


def _pick_block(total: int, preferred: int) -> int:
    """Largest divisor of `total` that is <= preferred (>=1)."""
    blk = min(preferred, total)
    while total % blk != 0:
        blk -= 1
    return blk


@functools.partial(jax.jit, static_argnames=("block_b", "block_f"))
def edges(
    x: jax.Array,
    u: jax.Array,
    grid_thr: jax.Array,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_f: int = DEFAULT_BLOCK_F,
) -> jax.Array:
    """Weighted edges of every candidate threshold stump.

    Args:
      x: ``(B, F)`` feature matrix.
      u: ``(B,)`` or ``(B, 1)`` signed weights ``w_i * y_i``.
      grid_thr: ``(F, NT)`` per-feature candidate thresholds.

    Returns:
      ``(F, NT)`` array, ``edges[f, t] = sum_i u_i * (2*(x[i,f] > grid_thr[f,t]) - 1)``.
    """
    b, f = x.shape
    f2, nt = grid_thr.shape
    assert f == f2, f"feature mismatch: x has {f}, grid_thr has {f2}"
    u2 = u.reshape(b, 1).astype(x.dtype)

    bb = _pick_block(b, block_b)
    fb = _pick_block(f, block_f)

    return pl.pallas_call(
        _edge_kernel,
        grid=(f // fb, b // bb),
        in_specs=[
            pl.BlockSpec((bb, fb), lambda fi, bi: (bi, fi)),
            pl.BlockSpec((bb, 1), lambda fi, bi: (bi, 0)),
            pl.BlockSpec((fb, nt), lambda fi, bi: (fi, 0)),
        ],
        out_specs=pl.BlockSpec((fb, nt), lambda fi, bi: (fi, 0)),
        out_shape=jax.ShapeDtypeStruct((f, nt), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, u2, grid_thr)


def vmem_footprint_bytes(block_b: int, block_f: int, nt: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM bytes for one grid step (DESIGN.md §7 perf estimate).

    Counts the X tile, u tile, threshold tile, output accumulator, and the
    dominant (Bb, Fb, NT) compare/select intermediate.
    """
    x_tile = block_b * block_f
    u_tile = block_b
    thr_tile = block_f * nt
    out_tile = block_f * nt
    intermediate = block_b * block_f * nt
    return dtype_bytes * (x_tile + u_tile + thr_tile + out_tile + intermediate)
