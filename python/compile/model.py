"""L2 — the Sparrow scan-batch compute graph (build-time JAX).

The Rust Scanner (L3) streams fixed-shape batches of in-memory examples
through this graph (AOT-lowered to HLO text, executed via PJRT):

  inputs (paper §4.1 "Incremental Updates"):
    x        (B, F)  feature block
    y        (B,)    labels in {-1, +1}
    w_s      (B,)    weight at last-sample time   ("w_s" in the paper)
    score_s  (B,)    strong-rule score at last-sample/last-update time
    model    (padded to T slots): feat_onehot (F, T), thr (T,), sign (T,),
             alpha (T,)  — unused slots carry alpha = 0
    grid_thr (F, NT) candidate-threshold grid owned by this worker

  outputs:
    scores   (B,)    H(x) under the current model         (cached by L3)
    w        (B,)    updated weights  w_s * exp(-y (H(x) - H_s(x)))
    edges    (F, NT) per-candidate weighted edges  sum_i w_i y_i h(x_i)
    sumw, sumw2      stopping-rule scalars  (W and V of Alg. 2)

The strong rule is evaluated with a one-hot feature-selection **matmul**
(x @ feat_onehot) so the gather maps onto the MXU; the candidate edges come
from the L1 Pallas kernel, which lowers into this same HLO module.

Everything here is build-time only: ``aot.py`` lowers `scan_batch` (and the
pure-jnp fallback + `predict`) once per shape configuration, and Rust never
imports Python again.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import edge_kernel, ref


def strong_rule_scores(x, feat_onehot, thr, sign, alpha):
    """``H(x)`` for a stump ensemble padded to fixed width T (MXU-friendly)."""
    xsel = x @ feat_onehot  # (B, T): one-hot matmul == batched feature gather
    preds = sign[None, :] * (2.0 * (xsel > thr[None, :]) - 1.0)
    return preds @ alpha


def scan_batch(x, y, w_s, score_s, feat_onehot, thr, sign, alpha, grid_thr):
    """Full scan step: incremental weights + candidate edges + stop scalars.

    Uses the L1 Pallas kernel for the candidate-edge reduction.
    Returns ``(scores, w, edges, sumw, sumw2)``.
    """
    scores = strong_rule_scores(x, feat_onehot, thr, sign, alpha)
    # Incremental update (paper §4.1): w = w_s * exp(-y * (H(x) - H_s(x))).
    w = w_s * jnp.exp(-y * (scores - score_s))
    u = w * y
    e = edge_kernel.edges(x, u, grid_thr)
    return scores, w, e, jnp.sum(w), jnp.sum(w * w)


def scan_batch_jnp(x, y, w_s, score_s, feat_onehot, thr, sign, alpha, grid_thr):
    """Same computation with the pure-jnp edge reduction (no Pallas).

    Lowered as a second artifact so the Rust runtime can A/B the kernel
    against XLA's own fusion of the einsum (bench: ablation_backend).
    """
    return ref.scan_batch(x, y, w_s, score_s, feat_onehot, thr, sign, alpha, grid_thr)


def predict(x, feat_onehot, thr, sign, alpha):
    """Scores-only graph for held-out evaluation (Figs. 3-4 series)."""
    return (strong_rule_scores(x, feat_onehot, thr, sign, alpha),)


def make_example_args(batch: int, features: int, tmax: int, nthr: int):
    """ShapeDtypeStructs for AOT lowering of `scan_batch`."""
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return (
        s((batch, features), f32),  # x
        s((batch,), f32),  # y
        s((batch,), f32),  # w_s
        s((batch,), f32),  # score_s
        s((features, tmax), f32),  # feat_onehot
        s((tmax,), f32),  # thr
        s((tmax,), f32),  # sign
        s((tmax,), f32),  # alpha
        s((features, nthr), f32),  # grid_thr
    )


def make_predict_args(batch: int, features: int, tmax: int):
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return (
        s((batch, features), f32),
        s((features, tmax), f32),
        s((tmax,), f32),
        s((tmax,), f32),
        s((tmax,), f32),
    )
