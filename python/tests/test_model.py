"""L2 correctness: scan_batch graph vs oracle; model semantics."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def _keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def _make_model(key, features, tmax, n_active):
    """Random stump ensemble padded to tmax slots."""
    kf, kt, ks, ka = jax.random.split(key, 4)
    feats = jax.random.randint(kf, (tmax,), 0, features)
    onehot = jax.nn.one_hot(feats, features, dtype=jnp.float32).T  # (F, T)
    thr = jax.random.normal(kt, (tmax,), dtype=jnp.float32)
    sign = jnp.where(jax.random.bernoulli(ks, shape=(tmax,)), 1.0, -1.0)
    alpha = jax.random.uniform(ka, (tmax,), minval=0.05, maxval=0.5)
    active = (jnp.arange(tmax) < n_active).astype(jnp.float32)
    return onehot, thr, sign, alpha * active


def _make_inputs(key, batch, features, nthr):
    kx, ky, kw, kt = jax.random.split(key, 4)
    x = jax.random.normal(kx, (batch, features), dtype=jnp.float32)
    y = jnp.where(jax.random.bernoulli(ky, 0.3, (batch,)), 1.0, -1.0)
    w_s = jnp.ones((batch,), jnp.float32)
    score_s = jnp.zeros((batch,), jnp.float32)
    grid_thr = jax.random.normal(kt, (features, nthr), dtype=jnp.float32)
    return x, y, w_s, score_s, grid_thr


class TestScanBatch:
    def test_pallas_path_matches_oracle(self):
        k0, k1 = _keys(0, 2)
        x, y, w_s, score_s, grid_thr = _make_inputs(k0, 128, 32, 4)
        onehot, thr, sign, alpha = _make_model(k1, 32, 16, 5)
        got = model.scan_batch(x, y, w_s, score_s, onehot, thr, sign, alpha, grid_thr)
        want = ref.scan_batch(x, y, w_s, score_s, onehot, thr, sign, alpha, grid_thr)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-4)

    def test_jnp_path_matches_oracle(self):
        k0, k1 = _keys(1, 2)
        x, y, w_s, score_s, grid_thr = _make_inputs(k0, 64, 16, 2)
        onehot, thr, sign, alpha = _make_model(k1, 16, 8, 3)
        got = model.scan_batch_jnp(x, y, w_s, score_s, onehot, thr, sign, alpha, grid_thr)
        want = ref.scan_batch(x, y, w_s, score_s, onehot, thr, sign, alpha, grid_thr)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-6)

    def test_empty_model_unit_weights(self):
        """With alpha == 0 everywhere, H == 0, so w == w_s and edges use u = w_s*y."""
        k0, k1 = _keys(2, 2)
        x, y, w_s, score_s, grid_thr = _make_inputs(k0, 64, 16, 2)
        onehot, thr, sign, alpha = _make_model(k1, 16, 8, 0)
        scores, w, e, sumw, sumw2 = model.scan_batch(
            x, y, w_s, score_s, onehot, thr, sign, alpha, grid_thr
        )
        np.testing.assert_allclose(scores, jnp.zeros(64), atol=1e-6)
        np.testing.assert_allclose(w, w_s, rtol=1e-6)
        np.testing.assert_allclose(sumw, 64.0, rtol=1e-5)
        np.testing.assert_allclose(e, ref.edges(x, w_s * y, grid_thr), rtol=1e-4, atol=1e-4)

    def test_incremental_equals_fresh(self):
        """Starting from (w_s, score_s) of model A and scanning with model B
        gives the same weights as starting fresh with model B.

        This is exactly the paper's incremental-update invariant: the stored
        (w_l, H_l) pair lets Scanner/Sampler share the weight computation.
        """
        k0, k1, k2 = _keys(3, 3)
        x, y, w0, s0, grid_thr = _make_inputs(k0, 64, 16, 2)
        onehot_a, thr_a, sign_a, alpha_a = _make_model(k1, 16, 8, 4)
        onehot_b, thr_b, sign_b, alpha_b = _make_model(k2, 16, 8, 6)

        # fresh: weights of model B from scratch
        _, w_fresh, _, _, _ = model.scan_batch(
            x, y, w0, s0, onehot_b, thr_b, sign_b, alpha_b, grid_thr
        )
        # incremental: first compute under A, then update A -> B
        scores_a, w_a, _, _, _ = model.scan_batch(
            x, y, w0, s0, onehot_a, thr_a, sign_a, alpha_a, grid_thr
        )
        _, w_inc, _, _, _ = model.scan_batch(
            x, y, w_a, scores_a, onehot_b, thr_b, sign_b, alpha_b, grid_thr
        )
        np.testing.assert_allclose(w_inc, w_fresh, rtol=1e-4, atol=1e-5)

    def test_weights_positive(self):
        k0, k1 = _keys(4, 2)
        x, y, w_s, score_s, grid_thr = _make_inputs(k0, 128, 32, 4)
        onehot, thr, sign, alpha = _make_model(k1, 32, 16, 16)
        _, w, _, sumw, sumw2 = model.scan_batch(
            x, y, w_s, score_s, onehot, thr, sign, alpha, grid_thr
        )
        assert jnp.all(w > 0)
        assert sumw > 0 and sumw2 > 0

    def test_effective_sample_size_shrinks_with_model(self):
        """A trained strong rule skews weights -> n_eff = (Σw)²/Σw² < B."""
        k0, k1 = _keys(5, 2)
        x, y, w_s, score_s, grid_thr = _make_inputs(k0, 256, 32, 4)
        onehot, thr, sign, alpha = _make_model(k1, 32, 16, 16)
        _, _, _, sumw, sumw2 = model.scan_batch(
            x, y, w_s, score_s, onehot, thr, sign, alpha, grid_thr
        )
        n_eff = float(sumw) ** 2 / float(sumw2)
        assert n_eff < 256.0


class TestPredict:
    def test_predict_matches_scan_scores(self):
        k0, k1 = _keys(6, 2)
        x, y, w_s, score_s, grid_thr = _make_inputs(k0, 64, 16, 2)
        onehot, thr, sign, alpha = _make_model(k1, 16, 8, 5)
        (scores_p,) = model.predict(x, onehot, thr, sign, alpha)
        scores_s, *_ = model.scan_batch(
            x, y, w_s, score_s, onehot, thr, sign, alpha, grid_thr
        )
        np.testing.assert_allclose(scores_p, scores_s, rtol=1e-6)

    def test_sign_flip_flips_scores(self):
        k0, k1 = _keys(7, 2)
        x, *_ = _make_inputs(k0, 32, 16, 2)
        onehot, thr, sign, alpha = _make_model(k1, 16, 8, 8)
        (s1,) = model.predict(x, onehot, thr, sign, alpha)
        (s2,) = model.predict(x, onehot, thr, -sign, alpha)
        np.testing.assert_allclose(s1, -s2, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    batch=st.sampled_from([16, 64, 128]),
    features=st.sampled_from([8, 16, 32]),
    tmax=st.sampled_from([4, 8, 16]),
    nthr=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_scan_matches_oracle(batch, features, tmax, nthr, seed):
    k0, k1 = _keys(seed, 2)
    x, y, w_s, score_s, grid_thr = _make_inputs(k0, batch, features, nthr)
    n_active = seed % (tmax + 1)
    onehot, thr, sign, alpha = _make_model(k1, features, tmax, n_active)
    got = model.scan_batch(x, y, w_s, score_s, onehot, thr, sign, alpha, grid_thr)
    want = ref.scan_batch(x, y, w_s, score_s, onehot, thr, sign, alpha, grid_thr)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)
