"""AOT lowering: HLO text artifacts are well-formed and parse-safe.

The actual execute-from-rust round trip is covered by rust integration
tests (rust/tests/runtime_roundtrip.rs) once `make artifacts` has run.
"""

import os
import re
import subprocess
import sys

import pytest

from compile import aot, model


class TestHloText:
    def test_scan_lowering_produces_hlo_module(self):
        text = aot.lower_scan(16, 8, 4, 2, use_pallas=True)
        assert text.startswith("HloModule")
        # entry computation with 9 parameters
        assert len(re.findall(r"parameter\(\d\)", text)) >= 9

    def test_scan_jnp_lowering_produces_hlo_module(self):
        text = aot.lower_scan(16, 8, 4, 2, use_pallas=False)
        assert text.startswith("HloModule")

    def test_predict_lowering(self):
        text = aot.lower_predict(16, 8, 4)
        assert text.startswith("HloModule")

    def test_root_is_tuple(self):
        """return_tuple=True: rust unwraps a tuple result."""
        text = aot.lower_scan(16, 8, 4, 2, use_pallas=False)
        assert "tuple(" in text.replace(") ", ")")

    def test_no_custom_calls(self):
        """interpret=True must leave no Mosaic custom-calls behind —
        the CPU PJRT plugin cannot execute them."""
        text = aot.lower_scan(16, 8, 4, 2, use_pallas=True)
        assert "custom-call" not in text or "mosaic" not in text.lower()

    def test_shapes_embedded(self):
        text = aot.lower_scan(16, 8, 4, 2, use_pallas=False)
        assert "f32[16,8]" in text  # x
        assert "f32[8,2]" in text  # grid_thr


class TestWriteIfChanged:
    def test_idempotent(self, tmp_path):
        p = str(tmp_path / "a.txt")
        assert aot.write_if_changed(p, "hello") is True
        assert aot.write_if_changed(p, "hello") is False
        assert aot.write_if_changed(p, "world") is True
        with open(p) as f:
            assert f.read() == "world"


class TestCli:
    def test_main_writes_artifacts_and_manifest(self, tmp_path):
        out = str(tmp_path / "artifacts")
        env = dict(os.environ)
        proc = subprocess.run(
            [
                sys.executable,
                "-m",
                "compile.aot",
                "--out-dir",
                out,
                "--configs",
                "16,8,4,2",
            ],
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            env=env,
            capture_output=True,
            text=True,
            timeout=600,
        )
        assert proc.returncode == 0, proc.stderr
        files = set(os.listdir(out))
        assert "manifest.txt" in files
        assert "scan_b16_f8_t4_n2.hlo.txt" in files
        assert "scanjnp_b16_f8_t4_n2.hlo.txt" in files
        assert "predict_b16_f8_t4.hlo.txt" in files
        with open(os.path.join(out, "manifest.txt")) as f:
            lines = [l for l in f.read().splitlines() if l and not l.startswith("#")]
        assert len(lines) == 3
        for line in lines:
            kv = dict(tok.split("=", 1) for tok in line.split())
            assert {"kind", "file", "batch", "features", "tmax", "nthr"} <= set(kv)


class TestExampleArgs:
    def test_make_example_args_shapes(self):
        args = model.make_example_args(32, 16, 8, 4)
        shapes = [a.shape for a in args]
        assert shapes == [
            (32, 16),
            (32,),
            (32,),
            (32,),
            (16, 8),
            (8,),
            (8,),
            (8,),
            (16, 4),
        ]

    def test_make_predict_args_shapes(self):
        args = model.make_predict_args(32, 16, 8)
        assert [a.shape for a in args] == [(32, 16), (16, 8), (8,), (8,), (8,)]
