"""L1 correctness: Pallas edge kernel vs pure-jnp oracle.

This is the CORE correctness signal for the kernel that ends up inside the
AOT-lowered scan-batch module. Includes a hypothesis sweep over shapes,
block sizes and value regimes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import edge_kernel, ref

jax.config.update("jax_enable_x64", False)


def _rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def _keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


class TestEdgeKernelBasic:
    def test_matches_oracle_default_blocks(self):
        kx, ku, kt = _keys(0, 3)
        x = _rand(kx, 512, 64)
        u = _rand(ku, 512)
        thr = _rand(kt, 64, 8)
        got = edge_kernel.edges(x, u, thr)
        want = ref.edges(x, u, thr)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

    def test_single_block(self):
        kx, ku, kt = _keys(1, 3)
        x = _rand(kx, 32, 8)
        u = _rand(ku, 32)
        thr = _rand(kt, 8, 4)
        got = edge_kernel.edges(x, u, thr, block_b=32, block_f=8)
        np.testing.assert_allclose(got, ref.edges(x, u, thr), rtol=1e-5, atol=1e-4)

    def test_multi_block_batch_reduction(self):
        """Batch axis split across several grid steps must accumulate."""
        kx, ku, kt = _keys(2, 3)
        x = _rand(kx, 256, 16)
        u = _rand(ku, 256)
        thr = _rand(kt, 16, 4)
        got = edge_kernel.edges(x, u, thr, block_b=32, block_f=8)
        np.testing.assert_allclose(got, ref.edges(x, u, thr), rtol=1e-5, atol=1e-4)

    def test_u_2d_accepted(self):
        kx, ku, kt = _keys(3, 3)
        x = _rand(kx, 64, 8)
        u = _rand(ku, 64).reshape(64, 1)
        thr = _rand(kt, 8, 4)
        np.testing.assert_allclose(
            edge_kernel.edges(x, u, thr), ref.edges(x, u, thr), rtol=1e-5, atol=1e-4
        )

    def test_zero_weights_give_zero_edges(self):
        kx, kt = _keys(4, 2)
        x = _rand(kx, 64, 8)
        u = jnp.zeros((64,), jnp.float32)
        thr = _rand(kt, 8, 4)
        assert jnp.all(edge_kernel.edges(x, u, thr) == 0.0)

    def test_uniform_weights_bounded_by_sum(self):
        """|edge| <= sum of |u| for every candidate (h in {-1,+1})."""
        kx, ku, kt = _keys(5, 3)
        x = _rand(kx, 128, 16)
        u = jnp.abs(_rand(ku, 128))
        thr = _rand(kt, 16, 4)
        e = edge_kernel.edges(x, u, thr)
        assert jnp.all(jnp.abs(e) <= jnp.sum(jnp.abs(u)) + 1e-4)

    def test_threshold_below_min_gives_plus_edge(self):
        """thr below all values -> h == +1 everywhere -> edge == sum(u)."""
        kx, ku = _keys(6, 2)
        x = jnp.abs(_rand(kx, 64, 4)) + 1.0  # all >= 1
        u = _rand(ku, 64)
        thr = jnp.zeros((4, 2), jnp.float32)  # all x > 0
        e = edge_kernel.edges(x, u, thr)
        np.testing.assert_allclose(e, jnp.full((4, 2), jnp.sum(u)), rtol=1e-5, atol=1e-4)

    def test_threshold_above_max_gives_minus_edge(self):
        kx, ku = _keys(7, 2)
        x = -jnp.abs(_rand(kx, 64, 4)) - 1.0  # all <= -1
        u = _rand(ku, 64)
        thr = jnp.zeros((4, 2), jnp.float32)
        e = edge_kernel.edges(x, u, thr)
        np.testing.assert_allclose(e, jnp.full((4, 2), -jnp.sum(u)), rtol=1e-5, atol=1e-4)

    def test_negating_u_negates_edges(self):
        kx, ku, kt = _keys(8, 3)
        x = _rand(kx, 64, 8)
        u = _rand(ku, 64)
        thr = _rand(kt, 8, 4)
        e1 = edge_kernel.edges(x, u, thr)
        e2 = edge_kernel.edges(x, -u, thr)
        np.testing.assert_allclose(e1, -e2, rtol=1e-5, atol=1e-4)

    def test_feature_mismatch_raises(self):
        kx, ku, kt = _keys(9, 3)
        with pytest.raises(AssertionError):
            edge_kernel.edges(_rand(kx, 16, 8), _rand(ku, 16), _rand(kt, 4, 2))


class TestPickBlock:
    def test_divisor_selected(self):
        assert edge_kernel._pick_block(100, 30) == 25
        assert edge_kernel._pick_block(128, 128) == 128
        assert edge_kernel._pick_block(128, 100) == 64
        assert edge_kernel._pick_block(7, 4) == 1

    def test_always_divides(self):
        for total in range(1, 70):
            for pref in range(1, 70):
                blk = edge_kernel._pick_block(total, pref)
                assert total % blk == 0
                assert 1 <= blk <= min(pref, total)


class TestVmemFootprint:
    def test_default_blocks_fit_vmem(self):
        bytes_ = edge_kernel.vmem_footprint_bytes(
            edge_kernel.DEFAULT_BLOCK_B, edge_kernel.DEFAULT_BLOCK_F, nt=8
        )
        assert bytes_ < 16 * 1024 * 1024  # TPU VMEM budget

    def test_monotone_in_blocks(self):
        a = edge_kernel.vmem_footprint_bytes(128, 64, 8)
        b = edge_kernel.vmem_footprint_bytes(256, 64, 8)
        c = edge_kernel.vmem_footprint_bytes(256, 128, 8)
        assert a < b < c


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([8, 32, 96, 128]),
    f=st.sampled_from([4, 8, 24, 32]),
    nt=st.sampled_from([1, 2, 4, 8]),
    bb=st.sampled_from([8, 16, 32, 64]),
    fb=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**16),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_hypothesis_shape_sweep(b, f, nt, bb, fb, seed, scale):
    """Kernel == oracle across shapes, block sizes, and value scales."""
    kx, ku, kt = _keys(seed, 3)
    x = _rand(kx, b, f) * scale
    u = _rand(ku, b)
    thr = _rand(kt, f, nt) * scale
    got = edge_kernel.edges(x, u, thr, block_b=bb, block_f=fb)
    want = ref.edges(x, u, thr)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    skew=st.floats(0.0, 20.0),
)
def test_hypothesis_skewed_weights(seed, skew):
    """Boosting drives exponentially skewed weights; kernel must stay exact."""
    kx, ku, kt, ks = _keys(seed, 4)
    x = _rand(kx, 64, 8)
    # weights spanning up to e^20 dynamic range, signed by labels
    logw = jax.random.uniform(ks, (64,), minval=-skew, maxval=0.0)
    y = jnp.sign(_rand(ku, 64)) + (jnp.sign(_rand(ku, 64)) == 0)
    u = jnp.exp(logw) * y
    thr = _rand(kt, 8, 4)
    got = edge_kernel.edges(x, u, thr, block_b=16, block_f=4)
    want = ref.edges(x, u, thr)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
