//! Quickstart: train a Sparrow worker on a small synthetic task.
//!
//!     cargo run --release --example quickstart
//!
//! Walks the whole public API surface in ~40 lines: synthesize data, write
//! the disk-resident store, configure a cluster, train, evaluate.

use std::time::Duration;

use sparrow::config::TrainConfig;
use sparrow::coordinator::train_cluster;
use sparrow::data::synth::SynthGen;
use sparrow::data::SynthConfig;
use sparrow::scanner::NativeBackend;

fn main() -> anyhow::Result<()> {
    // 1. Synthesize a splice-site-like task: rare positives, many weakly
    //    informative features (see DESIGN.md §3 for the rationale).
    let mut gen = SynthGen::new(SynthConfig {
        f: 32,
        pos_rate: 0.1,
        informative: 12,
        signal: 0.6,
        flip_rate: 0.02,
        seed: 42,
    });
    let dir = std::env::temp_dir().join("sparrow_quickstart");
    std::fs::create_dir_all(&dir)?;
    let store_path = dir.join("train.sprw");
    let store = gen.write_store(&store_path, 50_000)?;
    let test = gen.next_block(5_000);
    println!(
        "workload: {} train examples on disk ({:.1} MB), {} test",
        store.len(),
        store.data_bytes() as f64 / 1e6,
        test.n
    );

    // 2. Configure a two-worker TMSN cluster. Workers stripe the features,
    //    keep a 4096-example weighted sample in memory, and broadcast
    //    certified improvements to each other.
    let cfg = TrainConfig {
        num_workers: 2,
        sample_size: 4096,
        max_rules: 64,
        time_limit: Duration::from_secs(30),
        ..TrainConfig::default()
    };

    // 3. Train (native backend; pass `runtime::make_backend` for PJRT).
    let out = train_cluster(&cfg, &store_path, &test, "quickstart", &|_| {
        Ok(Box::new(NativeBackend))
    })?;

    // 4. Inspect.
    let p = out.series.points.last().unwrap();
    println!(
        "learned {} stumps in {:.2}s — test exp-loss {:.4}, AUPRC {:.4}",
        out.model.len(),
        out.elapsed.as_secs_f64(),
        p.exp_loss,
        p.auprc
    );
    let (sent, delivered, _) = out.net;
    println!("TMSN traffic: {sent} broadcasts, {delivered} deliveries");
    for w in &out.workers {
        println!(
            "  worker {}: certified {} rules locally, adopted {} remote models",
            w.id, w.found, w.accepts
        );
    }
    Ok(())
}
