//! Bring-your-own-workload demo: certified **asynchronous SGD** on a
//! linear model, over the *same* TMSN protocol and broadcast fabric the
//! boosting learner uses — no boosting types anywhere in the loop.
//!
//! The payload is a weight vector; the certificate is the model's
//! logistic loss on a shared held-out set every worker derives from the
//! run seed. Workers descend on private shards, broadcast only when they
//! certifiably improve the bound by ε ("tell me something new"), and
//! adopt strictly-better models the moment they arrive — interrupting a
//! descent chunk mid-way, exactly like the boosting scanner is
//! interrupted mid-pass. One worker runs 6x slow and one crashes early:
//! resilience is a property of the protocol, not of boosting.
//!
//!     cargo run --release --example async_sgd

use std::time::Duration;

use sparrow::harness;
use sparrow::metrics::EventKind;
use sparrow::network::NetConfig;
use sparrow::sgd::{train_sgd_cluster, SgdConfig};

fn main() -> anyhow::Result<()> {
    let scale = harness::bench_scale().max(0.1);
    let secs = 3.0 * scale;
    let cfg = SgdConfig {
        workers: 4,
        shard_n: (8_000.0 * scale) as usize + 500,
        valid_n: (2_000.0 * scale) as usize + 200,
        chunks: 1_000_000, // run to the time limit
        time_limit: Duration::from_secs_f64(secs),
        laggards: vec![(1, 6.0)],
        crashes: vec![(2, Duration::from_secs_f64(secs * 0.3))],
        net: NetConfig::default(),
        ..SgdConfig::default()
    };

    println!(
        "== certified async SGD over TMSN ({} workers, worker 1 at 6x slow, \
         worker 2 crashes at {:.1}s) ==",
        cfg.workers,
        secs * 0.3
    );
    let out = train_sgd_cluster(&cfg);

    println!(
        "\ncertified bound trajectory ({} improvements, zero model = ln 2 ≈ 0.6931):",
        out.bound_series.len()
    );
    for (t, loss) in &out.bound_series {
        println!("  t={:>7.3}s  held-out loss {loss:.5}", t.as_secs_f64());
    }
    assert!(
        out.bound_series.windows(2).all(|p| p[1].1 < p[0].1),
        "certified bound must be strictly decreasing"
    );

    println!("\nworkers:");
    for w in &out.workers {
        println!(
            "  worker {}: steps {:>7}  published {:>3}  accepted {:>3}  \
             rejected {:>3}  bound {:.5}{}",
            w.id,
            w.steps,
            w.published,
            w.accepts,
            w.rejects,
            w.loss,
            if w.crashed { "  [crashed]" } else { "" }
        );
    }
    let crashes = out.events.iter().filter(|e| e.kind == EventKind::Crash).count();
    let (sent, delivered, dropped) = out.net;
    println!(
        "\nnet: {sent} broadcasts, {delivered} delivered, {dropped} dropped; \
         {crashes} crash event(s); {:.2}s total",
        out.elapsed.as_secs_f64()
    );
    println!(
        "best certified held-out loss: {:.5} (from worker {}, seq {})",
        out.best.cert.loss, out.best.cert.origin, out.best.cert.seq
    );
    println!(
        "\n(the protocol layer — tmsn::{{Payload, Certified, Tmsn, Driver}} — is \
         identical to\n the boosting run; only the payload changed. See DESIGN.md §2 \
         and rust/src/sgd/.)"
    );
    Ok(())
}
