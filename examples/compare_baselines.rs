//! Table 1 + Figures 3/4 reproduction: Sparrow vs full-scan ("XGBoost")
//! vs GOSS ("LightGBM"), in-memory and off-memory tiers.
//!
//!     cargo run --release --example compare_baselines
//!
//! Prints the Table-1 analogue (time to an almost-optimal loss), the
//! Figure-3 (exp-loss vs time) and Figure-4 (AUPRC vs time, linear + log)
//! charts, and writes all series as CSV. The reference run is recorded in
//! EXPERIMENTS.md §E1/E3/E4.

use sparrow::baselines::DataSource;
use sparrow::data::DiskStore;
use sparrow::eval::MetricSeries;
use sparrow::harness::{self, Workload};
use sparrow::util::bench::Table;
use sparrow::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let secs = args.get_f64("time-limit", 45.0);
    let rules = args.get_usize("max-rules", 250);
    args.finish().map_err(anyhow::Error::msg)?;

    let w = Workload::standard();
    let (store_path, test) = w.materialize()?;
    let train_mem = DiskStore::open(&store_path)?.read_all()?;
    let bw = harness::off_memory_bandwidth();
    println!(
        "workload: {} train x {} features ({:.0} MB), {} test; off-memory bw {:.0} MB/s\n",
        w.train_n,
        w.features,
        (w.train_n * (w.features + 1) * 4) as f64 / 1e6,
        w.test_n,
        bw / 1e6
    );

    // ---- run everything ----------------------------------------------------
    let mut series: Vec<MetricSeries> = Vec::new();

    println!("running fullscan (in-memory)...");
    series.push(harness::run_fullscan(
        &DataSource::memory(train_mem.clone()),
        &test,
        harness::stop(rules, secs, 0.0),
        "fullscan-mem",
    ));
    println!("running fullscan (off-memory)...");
    series.push(harness::run_fullscan(
        &DataSource::disk(&store_path, bw)?,
        &test,
        harness::stop(rules, secs, 0.0),
        "fullscan-disk",
    ));
    println!("running goss (in-memory)...");
    series.push(harness::run_goss(
        &DataSource::memory(train_mem.clone()),
        &test,
        harness::stop(rules, secs, 0.0),
        "goss-mem",
    ));
    println!("running goss (off-memory)...");
    series.push(harness::run_goss(
        &DataSource::disk(&store_path, bw)?,
        &test,
        harness::stop(rules, secs, 0.0),
        "goss-disk",
    ));
    println!("running sparrow (1 worker, off-memory sampler)...");
    series.push(
        harness::run_sparrow(1, &store_path, &test, "sparrow-1", |c| {
            c.time_limit = std::time::Duration::from_secs_f64(secs);
            c.max_rules = rules;
            c.disk_bandwidth = bw;
        })?
        .series,
    );
    println!("running sparrow (10 workers, off-memory sampler)...");
    series.push(
        harness::run_sparrow(10, &store_path, &test, "sparrow-10", |c| {
            c.time_limit = std::time::Duration::from_secs_f64(secs);
            c.max_rules = rules;
            c.disk_bandwidth = bw;
        })?
        .series,
    );

    // ---- Table 1: time to almost-optimal loss ------------------------------
    // "almost optimal" = best loss any run achieved, +3% slack (the paper
    // uses 0.061 for its dataset the same way)
    let best = series
        .iter()
        .flat_map(|s| s.points.iter().map(|p| p.exp_loss))
        .fold(f64::INFINITY, f64::min);
    let target = best * 1.03;
    println!("\n=== Table 1 analogue: time to loss <= {target:.4} ===");
    let mut t = Table::new(&["Algorithm", "Memory tier", "Time (s)", "Final loss", "Final AUPRC"]);
    let tier = |label: &str| {
        if label.contains("mem") {
            "in-memory"
        } else {
            "off-memory"
        }
    };
    for s in &series {
        let p = s.points.last().unwrap();
        t.row(&[
            s.label.clone(),
            tier(&s.label).to_string(),
            harness::time_to(s, target),
            format!("{:.4}", p.exp_loss),
            format!("{:.4}", p.auprc),
        ]);
    }
    t.print();

    // ---- Figures 3 & 4 ------------------------------------------------------
    let refs: Vec<&MetricSeries> = series.iter().collect();
    println!("\n=== Figure 3: test exponential loss vs time ===");
    print!("{}", MetricSeries::ascii_chart(&refs, |p| p.exp_loss, 76, 14, false));
    println!("\n=== Figure 4 (left): AUPRC vs time ===");
    print!("{}", MetricSeries::ascii_chart(&refs, |p| p.auprc, 76, 14, false));
    println!("\n=== Figure 4 (right): AUPRC vs log-time ===");
    print!("{}", MetricSeries::ascii_chart(&refs, |p| p.auprc, 76, 14, true));

    // ---- persist -------------------------------------------------------------
    let dir = std::env::temp_dir().join("sparrow_compare");
    std::fs::create_dir_all(&dir)?;
    let mut csv = String::from("label,seconds,iterations,exp_loss,auprc\n");
    for s in &series {
        csv.push_str(&s.to_csv());
    }
    std::fs::write(dir.join("series.csv"), csv)?;
    std::fs::write(dir.join("table1.csv"), t.to_csv())?;
    println!("\nCSV written to {}", dir.display());
    Ok(())
}
