//! Resilience demonstration (§1/§2 claims): crash and slow down workers
//! mid-run; TMSN keeps making progress, while the bulk-synchronous
//! baseline stalls to the laggard's pace.
//!
//!     cargo run --release --example fault_tolerance

use std::time::Duration;

use sparrow::data::DiskStore;
use sparrow::harness::{self, Workload};
use sparrow::metrics::EventKind;

fn main() -> anyhow::Result<()> {
    let w = Workload::standard();
    let (store_path, test) = w.materialize()?;
    let secs = 12.0 * harness::bench_scale().max(0.25);

    println!("== TMSN under failures ==");

    // --- healthy cluster --------------------------------------------------
    let healthy = harness::run_sparrow(4, &store_path, &test, "healthy", |c| {
        c.time_limit = Duration::from_secs_f64(secs);
        c.max_rules = 10_000;
    })?;
    let hp = healthy.series.points.last().unwrap();
    println!(
        "healthy   : {} rules, loss {:.4}, auprc {:.4}",
        healthy.model.len(),
        hp.exp_loss,
        hp.auprc
    );

    // --- two of four workers crash early ----------------------------------
    let crashed = harness::run_sparrow(4, &store_path, &test, "crashed", |c| {
        c.time_limit = Duration::from_secs_f64(secs);
        c.max_rules = 10_000;
        c.crashes = vec![
            (1, Duration::from_secs_f64(secs * 0.2)),
            (3, Duration::from_secs_f64(secs * 0.3)),
        ];
    })?;
    let cp = crashed.series.points.last().unwrap();
    let crashes = crashed
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Crash)
        .count();
    println!(
        "2/4 crash : {} rules, loss {:.4}, auprc {:.4}   ({crashes} crash events)",
        crashed.model.len(),
        cp.exp_loss,
        cp.auprc
    );

    // --- one worker runs 8x slow -------------------------------------------
    let laggard = harness::run_sparrow(4, &store_path, &test, "laggard", |c| {
        c.time_limit = Duration::from_secs_f64(secs);
        c.max_rules = 10_000;
        c.laggards = vec![(2, 8.0)];
    })?;
    let lp = laggard.series.points.last().unwrap();
    println!(
        "1/4 @ 8x  : {} rules, loss {:.4}, auprc {:.4}",
        laggard.model.len(),
        lp.exp_loss,
        lp.auprc
    );

    // --- contrast: bulk-synchronous with the same laggard -------------------
    println!("\n== bulk-synchronous contrast (same laggard) ==");
    let train = DiskStore::open(&store_path)?.read_all()?;
    let bs_ok = harness::run_bulk_sync(
        &train,
        &test,
        4,
        vec![],
        harness::stop(10_000, secs, 0.0),
        "bs-healthy",
    );
    let bs_lag = harness::run_bulk_sync(
        &train,
        &test,
        4,
        vec![(2, 8.0)],
        harness::stop(10_000, secs, 0.0),
        "bs-laggard",
    );
    let iters =
        |s: &sparrow::eval::MetricSeries| s.points.last().map(|p| p.iterations).unwrap_or(0);
    println!(
        "bsp healthy: {} iterations in {secs:.0}s;  bsp with 8x laggard: {} iterations",
        iters(&bs_ok),
        iters(&bs_lag)
    );

    // --- summary -----------------------------------------------------------
    let tmsn_ratio = laggard.model.len() as f64 / healthy.model.len().max(1) as f64;
    let bsp_ratio = iters(&bs_lag) as f64 / iters(&bs_ok).max(1) as f64;
    println!(
        "\nprogress retained with one 8x laggard:  TMSN {:.0}%   BSP {:.0}%",
        tmsn_ratio * 100.0,
        bsp_ratio * 100.0
    );
    println!("(paper §1: TMSN's slowdown is proportional to the fraction of faulty machines;\n BSP runs at the speed of the slowest machine)");
    Ok(())
}
