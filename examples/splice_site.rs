//! End-to-end driver (DESIGN.md §End-to-end validation): the full system —
//! synthetic splice-site workload on disk, Sparrow TMSN cluster with the
//! disk-resident sampler, optional PJRT backend, baseline comparison — on
//! one real (scaled) workload, logging the loss curve.
//!
//!     cargo run --release --example splice_site [-- --backend xla-pallas]
//!
//! Results of a reference run are recorded in EXPERIMENTS.md.

use std::time::Duration;

use sparrow::baselines::DataSource;
use sparrow::config::{Backend, TrainConfig};
use sparrow::data::DiskStore;
use sparrow::eval::MetricSeries;
use sparrow::harness::{self, Workload};
use sparrow::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let backend = Backend::parse(&args.get_or("backend", "native")).map_err(anyhow::Error::msg)?;
    let workers = args.get_usize("workers", 4);
    let secs = args.get_f64("time-limit", 60.0);
    args.finish().map_err(anyhow::Error::msg)?;

    let w = Workload::large();
    println!(
        "== splice-site end-to-end ==  {} train x {} features, {} test (scale {})",
        w.train_n,
        w.features,
        w.test_n,
        harness::bench_scale()
    );
    let (store_path, test) = w.materialize()?;
    let store = DiskStore::open(&store_path)?;
    println!(
        "store: {} ({:.1} MB on disk)\n",
        store_path.display(),
        store.data_bytes() as f64 / 1e6
    );

    // --- Sparrow cluster -------------------------------------------------
    let mut cfg = TrainConfig {
        num_workers: workers,
        sample_size: 4096,
        max_rules: 300,
        time_limit: Duration::from_secs_f64(secs),
        backend,
        eval_interval: Duration::from_millis(200),
        ..TrainConfig::default()
    };
    if backend != Backend::Native {
        // the shipped artifacts are lowered for (B=1024, F=256, T=256, NT=8);
        // the large workload uses F=64, so xla backends need a matching
        // artifact: fall back with a clear message instead of failing deep.
        cfg.batch = 1024;
        cfg.nthr = 8;
    }
    let features = store.num_features();
    let cfg2 = cfg.clone();
    let outcome = sparrow::coordinator::train_cluster(
        &cfg,
        &store_path,
        &test,
        "sparrow",
        &move |_| sparrow::runtime::make_backend(&cfg2, features),
    )?;

    println!("sparrow ({} workers, {} backend):", workers, args.get_or("backend", "native"));
    println!(
        "  {} rules, bound {:.4}, {:.1}s elapsed",
        outcome.model.len(),
        outcome.loss_bound,
        outcome.elapsed.as_secs_f64()
    );
    let p = outcome.series.points.last().unwrap();
    println!("  test exp-loss {:.4}  AUPRC {:.4}", p.exp_loss, p.auprc);

    // --- baseline for context (fullscan, in-memory) ----------------------
    let train_mem = store.read_all()?;
    let fs = harness::run_fullscan(
        &DataSource::memory(train_mem),
        &test,
        harness::stop(300, secs, 0.0),
        "fullscan",
    );
    let fp = fs.points.last().unwrap();
    println!(
        "fullscan (in-memory): test exp-loss {:.4}  AUPRC {:.4}  ({:.1}s)",
        fp.exp_loss,
        fp.auprc,
        fp.elapsed.as_secs_f64()
    );

    // --- loss curves ------------------------------------------------------
    println!("\nexp-loss vs time (lower is better):");
    print!(
        "{}",
        MetricSeries::ascii_chart(&[&outcome.series, &fs], |p| p.exp_loss, 72, 14, false)
    );
    println!("\nAUPRC vs time (higher is better):");
    print!(
        "{}",
        MetricSeries::ascii_chart(&[&outcome.series, &fs], |p| p.auprc, 72, 14, false)
    );

    // --- persist ----------------------------------------------------------
    let out_dir = std::env::temp_dir().join("sparrow_splice_site");
    std::fs::create_dir_all(&out_dir)?;
    std::fs::write(out_dir.join("sparrow_series.csv"), outcome.series.to_csv())?;
    std::fs::write(out_dir.join("fullscan_series.csv"), fs.to_csv())?;
    std::fs::write(out_dir.join("timeline.txt"), outcome.timeline(100))?;
    println!("\nseries + timeline written to {}", out_dir.display());
    Ok(())
}
