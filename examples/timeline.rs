//! Figure 1 reproduction: the TMSN execution timeline.
//!
//!     cargo run --release --example timeline
//!
//! Four workers on a latency-injected broadcast fabric; the printed
//! timeline shows exactly the paper's Figure-1 dynamics: a worker finds an
//! improvement (F), broadcasts it (B), and the others interrupt their
//! scanners (!) at different times depending on network latency — or
//! discard the message (.) if they already hold something better.

use std::time::Duration;

use sparrow::config::TrainConfig;
use sparrow::harness::Workload;
use sparrow::metrics::events::to_jsonl;
use sparrow::network::NetConfig;
use sparrow::scanner::NativeBackend;

fn main() -> anyhow::Result<()> {
    let w = Workload::standard();
    let (store_path, test) = w.materialize()?;

    let cfg = TrainConfig {
        num_workers: 4,
        sample_size: 4096,
        max_rules: 24,
        time_limit: Duration::from_secs(30),
        // visible network delays: 20-60ms links (EC2-like cross-AZ scale,
        // exaggerated so the deliveries spread out in the rendering)
        net: NetConfig {
            base_latency: Duration::from_millis(20),
            jitter_mean: Duration::from_millis(15),
            bandwidth_bytes_per_sec: 10e6,
            drop_rate: 0.0,
            latency_multipliers: vec![1.0, 1.0, 2.5, 1.0, 1.0],
            seed: 0xF16,
        },
        eval_interval: Duration::from_millis(100),
        ..TrainConfig::default()
    };
    let outcome = sparrow::coordinator::train_cluster(&cfg, &store_path, &test, "fig1", &|_| {
        Ok(Box::new(NativeBackend))
    })?;

    println!("{}", outcome.timeline(100));
    println!("model: {} rules, bound {:.4}", outcome.model.len(), outcome.loss_bound);
    let (sent, delivered, dropped) = outcome.net;
    println!("fabric: {sent} broadcasts → {delivered} deliveries ({dropped} dropped)");

    // per-worker protocol counters — the "no one waits" evidence: every
    // worker keeps finding/adopting without any barrier
    for wk in &outcome.workers {
        println!(
            "  w{}: found {:2}  accepted {:2}  rejected {:2}  resamples {}",
            wk.id, wk.found, wk.accepts, wk.rejects, wk.resamples
        );
    }

    let out = std::env::temp_dir().join("sparrow_timeline_events.jsonl");
    std::fs::write(&out, to_jsonl(&outcome.events))?;
    println!("\nfull event log: {}", out.display());
    Ok(())
}
